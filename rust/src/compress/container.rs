//! The compressed-forest container format (`RFCZ`).
//!
//! ```text
//! ┌──────────┬─────────────────────────────────────────────────────────┐
//! │ HEADER   │ magic, version, target kind, trees, features, codecs,   │
//! │          │ conditioning, section byte offsets                      │
//! │ TABLES   │ per-feature split-value alphabets + regression fit      │
//! │          │ value alphabet (the 64-bit-exact side tables)           │
//! │ CLUSMAP  │ context-key → cluster id, per model family              │
//! │ DICTS    │ per-cluster codebooks: canonical-Huffman length tables, │
//! │          │ or arithmetic frequency models for two-class fits       │
//! │ STRUCT   │ LZSS(concatenated Zaks sequences)                       │
//! │ VARS     │ per-tree byte offsets + Huffman-coded variable names    │
//! │ SPLITS   │ per-tree byte offsets + Huffman-coded split ranks       │
//! │ FITS     │ per-tree byte offsets + Huffman/arith-coded fits        │
//! └──────────┴─────────────────────────────────────────────────────────┘
//! ```
//!
//! Every payload section is **per-tree byte aligned** with an explicit
//! offset table, which is what makes prediction from the compressed format
//! (paper §5) a seek + prefix-decode instead of a full decompression.
//! The container is fully self-describing: decompression requires no side
//! information (in particular, unlike the paper's observation-index coding
//! of numeric split values, the actual values live in TABLES — a standalone
//! decoder cannot assume access to the training data).
//!
//! ## Stage-chain grammar (version 2)
//!
//! A container whose [`CompressOptions::chains`][1] are non-empty is
//! written with [`VERSION_CHAINED`]; its header carries the three
//! per-section chains right after the conditioning byte:
//!
//! ```text
//! chains     := chain chain chain          ; structure, split-tables, fits
//! chain      := varint(len ≤ 8) stage*
//! stage      := tag:u8 [width:u8]          ; width only for tag 5
//! tag        := 0 lzss | 1 huff | 2 arith | 3 delta | 4 xor
//!             | 5 split<width∈2..=16> | 6 f32 | 7 bf16
//! ```
//!
//! A non-empty structure chain writes STRUCT with mode byte 2 followed by
//! the chain-coded payload; a non-empty split-tables chain writes each
//! numeric TABLES entry with kind 3 (`varint(payload len)`, byte-align,
//! payload); a non-empty fit chain replaces the fit table's `f64pack`
//! block the same way. Decoders reject a chain-coded section whose header
//! chain is empty, and validate chains on parse (lossy stages only at the
//! head of a regression fit chain). Version-1 containers carry no chain
//! bytes and parse with all chains empty — byte-for-byte the
//! pre-stage-pipeline format.
//!
//! [1]: super::pipeline::CompressOptions

use crate::coding::arith::FreqModel;
use crate::coding::bitio::{BitReader, BitWriter};
use crate::coding::f64pack::{self, F64Codec};
use crate::coding::huffman::HuffmanCode;
use crate::coding::stage::{self, SectionChains};
use crate::model::extract::{SplitAlphabet, ValueAlphabets};
use crate::model::keys::{ContextKey, ModelConditioning, ROOT_FATHER};
use crate::util::mmap::Mmap;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Container file magic (`RFCZ`).
pub const MAGIC: &[u8; 4] = b"RFCZ";
/// Legacy (chainless) container version: the fixed four-stage pipeline.
/// Written whenever every stage chain is empty, so default-option output
/// is byte-identical to the pre-stage-pipeline encoder.
pub const VERSION: u8 = 1;
/// Chained container version: the header additionally carries the three
/// per-section stage chains (see [`crate::coding::stage`]). Written only
/// when at least one chain is non-empty; version-1 containers parse
/// unchanged with empty chains.
pub const VERSION_CHAINED: u8 = 2;

/// A parsed container's byte source. Payload sections alias this buffer
/// wherever it lives:
///
/// * [`SharedBytes::Heap`] — an `Arc<[u8]>`, the freshly-compressed /
///   network-received case (the model store's RAM tier);
/// * [`SharedBytes::Mapped`] — a memory-mapped spill file
///   ([`crate::util::mmap::Mmap`]): reloading an evicted model is an `mmap`
///   plus a header parse — no `read`, no payload memcpy, the kernel pages
///   bytes in on first decode.
/// * [`SharedBytes::View`] — a sub-range of another shared buffer: a pack
///   member ([`crate::pack`]) aliasing its archive's single mapping, so one
///   `mmap` of a pack serves every member without per-member copies.
///
/// Cloning is a refcount bump in every case, so any number of parses and
/// predictors keep sharing one resident copy (the zero-copy contract of
/// [`ParsedContainer`]).
#[derive(Clone)]
pub enum SharedBytes {
    /// A heap buffer (freshly compressed or read into memory).
    Heap(Arc<[u8]>),
    /// A read-only file mapping (spill reload, pack archive).
    Mapped(Arc<Mmap>),
    /// A bounds-checked sub-range of another buffer (a pack member's
    /// span within its archive's single mapping).
    View {
        /// The buffer this view aliases.
        base: Arc<SharedBytes>,
        /// Start of the view within `base`.
        offset: usize,
        /// Length of the view in bytes.
        len: usize,
    },
}

impl SharedBytes {
    /// The underlying bytes, wherever they live.
    pub fn as_slice(&self) -> &[u8] {
        match self {
            SharedBytes::Heap(b) => b,
            SharedBytes::Mapped(m) => m,
            SharedBytes::View { base, offset, len } => &base.as_slice()[*offset..*offset + *len],
        }
    }

    /// A zero-copy sub-range view of this buffer (bounds-checked). Views of
    /// views collapse onto the root buffer, so chains never build up.
    pub fn slice(&self, offset: usize, len: usize) -> Result<SharedBytes> {
        let end = offset.checked_add(len).context("view span overflow")?;
        if end > self.len() {
            bail!("view {offset}..{end} out of bounds (buffer holds {})", self.len());
        }
        Ok(match self {
            SharedBytes::View { base, offset: base_off, .. } => SharedBytes::View {
                base: base.clone(),
                offset: base_off + offset,
                len,
            },
            other => SharedBytes::View {
                base: Arc::new(other.clone()),
                offset,
                len,
            },
        })
    }

    /// Address of the first byte (pointer-identity tests use this to
    /// assert zero-copy parsing).
    pub fn as_ptr(&self) -> *const u8 {
        self.as_slice().as_ptr()
    }

    /// Buffer length in bytes.
    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    /// Whether the buffer is empty.
    pub fn is_empty(&self) -> bool {
        self.as_slice().is_empty()
    }

    /// Whether this buffer is a live file mapping (the tiered store's
    /// reload path; heap buffers and the non-unix read fallback are not).
    /// A view is mapped when its base is.
    pub fn is_mapped(&self) -> bool {
        match self {
            SharedBytes::Heap(_) => false,
            SharedBytes::Mapped(m) => m.is_mapped(),
            SharedBytes::View { base, .. } => base.is_mapped(),
        }
    }
}

impl std::ops::Deref for SharedBytes {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl std::fmt::Debug for SharedBytes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SharedBytes")
            .field("len", &self.len())
            .field("mapped", &self.is_mapped())
            .field("view", &matches!(self, SharedBytes::View { .. }))
            .finish()
    }
}

impl From<Arc<[u8]>> for SharedBytes {
    fn from(b: Arc<[u8]>) -> Self {
        SharedBytes::Heap(b)
    }
}

impl From<Vec<u8>> for SharedBytes {
    fn from(b: Vec<u8>) -> Self {
        SharedBytes::Heap(Arc::from(b))
    }
}

impl From<Arc<Mmap>> for SharedBytes {
    fn from(m: Arc<Mmap>) -> Self {
        SharedBytes::Mapped(m)
    }
}

impl From<Mmap> for SharedBytes {
    fn from(m: Mmap) -> Self {
        SharedBytes::Mapped(Arc::new(m))
    }
}

/// Codec used for the FITS section.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FitCodec {
    /// Canonical Huffman (regression / multiclass).
    Huffman,
    /// Arithmetic coding (two-class classification, §4).
    Arith,
    /// Raw 64-bit IEEE values inline (regression escape hatch: when fits
    /// are mostly unique, table + index coding costs *more* than the 64
    /// bits the paper's "orthodox losslessness" already pays per fit —
    /// the encoder picks whichever is smaller, cf. the paper's Liberty⁺
    /// fits barely compressing: 122.1 → 118 MB).
    Raw64,
}

/// Per-section byte sizes — the paper's Table 1 breakdown.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SectionSizes {
    /// Fixed header + feature metadata bytes.
    pub header: u64,
    /// TABLES minus the fit value table (split-value alphabets).
    pub split_value_tables: u64,
    /// Regression fit value alphabet (64 bits per distinct fit).
    pub fit_value_table: u64,
    /// Context-key → cluster assignment maps.
    pub cluster_maps: u64,
    /// Per-cluster Huffman codebooks.
    pub dictionaries: u64,
    /// Zaks tree-structure stream.
    pub structure: u64,
    /// Variable-name (split feature) stream.
    pub var_names: u64,
    /// Split-value stream.
    pub split_values: u64,
    /// Leaf/node fit stream.
    pub fits: u64,
}

impl SectionSizes {
    /// Total container bytes across every section.
    pub fn total(&self) -> u64 {
        self.header
            + self.split_value_tables
            + self.fit_value_table
            + self.cluster_maps
            + self.dictionaries
            + self.structure
            + self.var_names
            + self.split_values
            + self.fits
    }

    /// Paper-style grouping: dict column = dictionaries + cluster maps +
    /// split-value tables + header (all decode side-information), fits
    /// column includes the fit value table.
    pub fn paper_columns(&self) -> PaperColumns {
        PaperColumns {
            structure: self.structure,
            var_names: self.var_names,
            split_values: self.split_values,
            fits: self.fits + self.fit_value_table,
            dict: self.header + self.split_value_tables + self.cluster_maps + self.dictionaries,
        }
    }
}

/// The five columns of the paper's Table 1.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PaperColumns {
    /// Tree-structure bytes (Zaks stream).
    pub structure: u64,
    /// Variable-name bytes.
    pub var_names: u64,
    /// Split-value bytes.
    pub split_values: u64,
    /// Fit bytes.
    pub fits: u64,
    /// Dictionary bytes (tables + cluster maps + codebooks).
    pub dict: u64,
}

impl PaperColumns {
    /// Sum over the five columns.
    pub fn total(&self) -> u64 {
        self.structure + self.var_names + self.split_values + self.fits + self.dict
    }
}

/// Feature metadata kept in the header (kind drives split decoding; names
/// reproduce the original model exactly).
#[derive(Debug, Clone, PartialEq)]
pub struct FeatureMeta {
    /// Feature name, reproduced exactly on decompression.
    pub name: String,
    /// `None` = numeric; `Some(levels)` = categorical.
    pub levels: Option<u32>,
}

/// Parsed header + side tables; payload sections stay as **zero-copy
/// views** into the shared container buffer (decoded on demand).
///
/// The whole container lives in one `Arc<[u8]>`; parsing records only
/// `(offset, length)` spans for the payload sections, so building a
/// [`crate::compress::CompressedPredictor`] allocates nothing per section
/// and any number of parsed containers/predictors can share one buffer
/// (the model store's resident-bytes accounting counts the buffer once).
#[derive(Debug, Clone)]
pub struct ParsedContainer {
    /// Whether the forest classifies (vs regresses).
    pub classification: bool,
    /// Number of classes (classification only).
    pub classes: u32,
    /// Number of trees in the forest.
    pub n_trees: usize,
    /// Per-feature metadata from the header.
    pub features: Vec<FeatureMeta>,
    /// How fit values are coded.
    pub fit_codec: FitCodec,
    /// The `(depth, father)` conditioning scheme of the tree models.
    pub conditioning: ModelConditioning,
    /// The per-section stage chains this container was encoded with
    /// (all empty for a version-1 legacy container).
    pub chains: SectionChains,
    /// Decoded split/fit value alphabets (TABLES section).
    pub alphabets: ValueAlphabets,
    /// Per-feature: `Some(ranks)` when the numeric split alphabet is
    /// **dataset-indexed** (paper mode §3.2.2: each used threshold is the
    /// rank of an observation value; the actual f64s are regenerated from
    /// the training data via [`ParsedContainer::attach_dataset`]), `None`
    /// when the values are stored in the container.
    pub indexed_splits: Vec<Option<Vec<u64>>>,
    /// context-key → cluster, per model family
    pub vn_map: BTreeMap<ContextKey, u32>,
    /// Per-feature context-key → cluster maps for split values.
    pub split_maps: Vec<BTreeMap<ContextKey, u32>>,
    /// Context-key → cluster map for fits.
    pub fit_map: BTreeMap<ContextKey, u32>,
    /// per-cluster codebooks
    pub vn_dicts: Vec<HuffmanCode>,
    /// Per-feature, per-cluster split-value codebooks.
    pub split_dicts: Vec<Vec<HuffmanCode>>,
    /// Per-cluster fit codebooks.
    pub fit_dicts: Vec<HuffmanCode>,
    /// Per-cluster arithmetic-coder fit models.
    pub fit_models: Vec<FreqModel>,
    /// sign/exponent codec for [`FitCodec::Raw64`] fit streams
    pub fit_raw_codec: Option<F64Codec>,
    /// decoded concatenated Zaks bits
    pub zaks_bits: Vec<bool>,
    /// per-tree byte ranges (start, end) into each payload section
    pub vars_ranges: Vec<(usize, usize)>,
    /// Per-tree byte ranges into the split-value section.
    pub splits_ranges: Vec<(usize, usize)>,
    /// Per-tree byte ranges into the fit section.
    pub fits_ranges: Vec<(usize, usize)>,
    /// the shared container buffer (heap or mmap); payload sections are
    /// views into it
    buf: SharedBytes,
    /// process-unique id of this parse, never reused — the plan cache's
    /// model key (see [`crate::compress::flat::PlanCache`]). Clones share
    /// the id: they alias the same streams, so their plans are identical.
    plan_id: u64,
    /// absolute byte spans of the payload sections within `buf`
    vars_span: (usize, usize),
    splits_span: (usize, usize),
    fits_span: (usize, usize),
    /// Per-section byte accounting of this container.
    pub sizes: SectionSizes,
}

/// Monotone source of [`ParsedContainer::plan_id`] values (0 is never
/// issued, so it can serve as a sentinel).
static NEXT_PLAN_ID: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(1);

impl ParsedContainer {
    /// The shared container buffer this parse aliases (no copies were made
    /// of the payload sections; everything below points into this). Heap or
    /// mmap — see [`SharedBytes`].
    pub fn buffer(&self) -> &SharedBytes {
        &self.buf
    }

    /// Process-unique identity of this parse, used to key decoded flat-tree
    /// plans. Unlike a buffer address it is never reused, so a cached plan
    /// can never alias a different (later) model.
    pub fn plan_id(&self) -> u64 {
        self.plan_id
    }

    /// The VARS payload section — a view into the shared buffer.
    pub fn vars_bytes(&self) -> &[u8] {
        &self.buf[self.vars_span.0..self.vars_span.1]
    }

    /// The SPLITS payload section — a view into the shared buffer.
    pub fn splits_bytes(&self) -> &[u8] {
        &self.buf[self.splits_span.0..self.splits_span.1]
    }

    /// The FITS payload section — a view into the shared buffer.
    pub fn fits_bytes(&self) -> &[u8] {
        &self.buf[self.fits_span.0..self.fits_span.1]
    }

    /// Tree `t`'s variable-name stream (zero-copy slice).
    pub fn tree_vars(&self, t: usize) -> &[u8] {
        let (s, e) = self.vars_ranges[t];
        &self.vars_bytes()[s..e]
    }

    /// Tree `t`'s split-rank stream (zero-copy slice).
    pub fn tree_splits(&self, t: usize) -> &[u8] {
        let (s, e) = self.splits_ranges[t];
        &self.splits_bytes()[s..e]
    }

    /// Tree `t`'s fit stream (zero-copy slice).
    pub fn tree_fits(&self, t: usize) -> &[u8] {
        let (s, e) = self.fits_ranges[t];
        &self.fits_bytes()[s..e]
    }

    /// Absolute byte span `[start, end)` of the decode side information
    /// (TABLES + CLUSMAP + DICTS) within the serialized container — the
    /// region a model pack ([`crate::pack`]) excises into a shared blob when
    /// several members carry byte-identical coder tables. Every section is
    /// byte-aligned, so the span boundaries are exact.
    ///
    /// Only meaningful for a container parsed from its full standalone
    /// bytes (a [`parse_packed`] member's side info lives in the blob, not
    /// in its buffer).
    pub fn side_info_span(&self) -> (usize, usize) {
        let start = self.sizes.header as usize;
        let len = (self.sizes.split_value_tables
            + self.sizes.fit_value_table
            + self.sizes.cluster_maps
            + self.sizes.dictionaries) as usize;
        (start, start + len)
    }

    /// Whether any split alphabet is dataset-indexed (paper mode) and must
    /// be regenerated via [`Self::attach_dataset`] before decoding.
    pub fn needs_dataset(&self) -> bool {
        self.indexed_splits.iter().any(|x| x.is_some())
    }

    /// Regenerate dataset-indexed split alphabets from the training data:
    /// map each stored rank onto the column's sorted unique values.
    pub fn attach_dataset(&mut self, ds: &crate::data::Dataset) -> Result<()> {
        if ds.num_features() != self.features.len() {
            bail!(
                "dataset has {} features, container expects {}",
                ds.num_features(),
                self.features.len()
            );
        }
        for f in 0..self.features.len() {
            if let Some(ranks) = &self.indexed_splits[f] {
                let uniq = crate::model::extract::ValueAlphabets::column_unique(ds, f)?;
                let vals: Result<Vec<f64>> = ranks
                    .iter()
                    .map(|&r| {
                        uniq.get(r as usize).copied().with_context(|| {
                            format!(
                                "feature {f}: rank {r} beyond the dataset's {} unique values \
                                 (wrong dataset attached?)",
                                uniq.len()
                            )
                        })
                    })
                    .collect();
                self.alphabets.splits[f] = SplitAlphabet::Numeric(vals?);
                self.indexed_splits[f] = None; // resolved
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------- encoding

/// Everything the encoder assembled, ready for serialization.
///
/// The side information (alphabets, cluster maps, codebooks, chains) is
/// **borrowed** from the frozen [`CodecPlan`](super::pipeline::CodecPlan):
/// a cohort encode ([`crate::pack::compress_cohort`]) serializes every
/// member straight from the one shared plan instead of cloning the maps
/// and dictionaries per member. Only the per-member payloads are owned.
pub struct ContainerBuilder<'a> {
    /// The frozen codec plan: target kind, feature metadata, alphabets,
    /// cluster maps, codebooks, and the per-section stage chains.
    pub plan: &'a super::pipeline::CodecPlan,
    /// Number of trees in this member.
    pub n_trees: usize,
    /// STRUCT payload (mode byte + encoded Zaks stream), already encoded.
    pub struct_bytes: Vec<u8>,
    /// per-tree payloads, each byte-aligned
    pub vars_trees: Vec<Vec<u8>>,
    /// Per-tree split-value payloads, each byte-aligned.
    pub splits_trees: Vec<Vec<u8>>,
    /// Per-tree fit payloads, each byte-aligned.
    pub fits_trees: Vec<Vec<u8>>,
}

fn write_conditioning(w: &mut BitWriter, c: ModelConditioning) {
    let v = match c {
        ModelConditioning::DepthFather => 0u64,
        ModelConditioning::DepthOnly => 1,
        ModelConditioning::None => 2,
    };
    w.write_bits(v, 8);
}

fn read_conditioning(r: &mut BitReader) -> Result<ModelConditioning> {
    Ok(match r.read_bits(8).context("conditioning")? {
        0 => ModelConditioning::DepthFather,
        1 => ModelConditioning::DepthOnly,
        2 => ModelConditioning::None,
        v => bail!("unknown conditioning tag {v}"),
    })
}

fn write_map(w: &mut BitWriter, map: &BTreeMap<ContextKey, u32>) {
    w.write_varint(map.len() as u64);
    for (k, &c) in map {
        w.write_varint(k.depth as u64);
        // father: ROOT_FATHER encoded as 0, features as f+1
        let father = if k.father == ROOT_FATHER { 0 } else { k.father as u64 + 1 };
        w.write_varint(father);
        w.write_varint(c as u64);
    }
}

/// Checked `u64 → usize` for counts and section lengths read from container
/// or pack-archive bytes. On 32-bit targets (or corrupt/adversarial
/// headers) an oversized value surfaces a typed error instead of silently
/// truncating — a truncated length would pass the plausibility caps and
/// then mis-slice the buffer. Shared with [`crate::pack::format`].
pub(crate) fn cast_usize(v: u64, what: &str) -> Result<usize> {
    usize::try_from(v)
        .ok()
        .with_context(|| format!("{what} {v} does not fit this platform's usize"))
}

fn read_map(r: &mut BitReader) -> Result<BTreeMap<ContextKey, u32>> {
    let n_raw = r.read_varint().context("map len")?;
    if n_raw > 50_000_000 {
        bail!("implausible map size {n_raw}");
    }
    let n = cast_usize(n_raw, "map size")?;
    let mut map = BTreeMap::new();
    for _ in 0..n {
        let depth = r.read_varint().context("map depth")? as u16;
        let father_raw = r.read_varint().context("map father")?;
        let father = if father_raw == 0 { ROOT_FATHER } else { (father_raw - 1) as u32 };
        let cluster = r.read_varint().context("map cluster")? as u32;
        map.insert(ContextKey { depth, father }, cluster);
    }
    Ok(map)
}

fn write_payload_section(w: &mut BitWriter, trees: &[Vec<u8>]) {
    w.write_varint(trees.len() as u64);
    for t in trees {
        w.write_varint(t.len() as u64);
    }
    w.align_byte();
    for t in trees {
        for &b in t {
            w.write_byte(b);
        }
    }
}

/// Read a payload section's offset table, then *seek past* the payload body
/// instead of copying it: the returned span indexes the source buffer
/// directly (the zero-copy contract of [`ParsedContainer`]).
fn read_payload_spans(
    r: &mut BitReader,
    buf_len: usize,
) -> Result<(Vec<(usize, usize)>, (usize, usize))> {
    let n_raw = r.read_varint().context("payload tree count")?;
    if n_raw > 50_000_000 {
        bail!("implausible tree count {n_raw}");
    }
    let n = cast_usize(n_raw, "payload tree count")?;
    let mut lens = Vec::with_capacity(n);
    // lengths accumulate in u64 and are range-checked BEFORE the usize
    // casts: a 32-bit target must reject, not truncate, oversized sections
    let mut total = 0u64;
    for _ in 0..n {
        let l = r.read_varint().context("payload tree len")?;
        total = total.checked_add(l).context("payload length overflow")?;
        if total > (1u64 << 33) {
            bail!("implausible payload size {total}");
        }
        lens.push(cast_usize(l, "payload tree len")?);
    }
    let total = cast_usize(total, "payload section size")?;
    r.align_byte();
    let start = cast_usize(r.bit_pos() / 8, "payload offset")?;
    let end = start.checked_add(total).context("payload span overflow")?;
    if end > buf_len {
        bail!("payload section truncated ({total} bytes at {start}, buffer holds {buf_len})");
    }
    r.seek_bits(end as u64 * 8);
    let mut ranges = Vec::with_capacity(n);
    let mut off = 0usize;
    for l in lens {
        ranges.push((off, off + l));
        off += l;
    }
    Ok((ranges, (start, end)))
}

impl ContainerBuilder<'_> {
    /// Serialize to the final container bytes + the section size breakdown.
    ///
    /// Fails only when a lossy convert stage overflows its narrower target
    /// format; with empty chains (the default) serialization is infallible
    /// and byte-identical to the pre-stage-pipeline encoder.
    pub fn serialize(&self) -> Result<(Vec<u8>, SectionSizes)> {
        let p = self.plan;
        let mut w = BitWriter::new();
        let mut sizes = SectionSizes::default();

        // ---- HEADER ----
        for &b in MAGIC {
            w.write_byte(b);
        }
        // chainless plans keep emitting version 1 so the default encoder's
        // output stays byte-for-byte what the fixed pipeline produced
        let version = if p.chains.is_default() { VERSION } else { VERSION_CHAINED };
        w.write_bits(version as u64, 8);
        w.write_bits(p.classification as u64, 8);
        w.write_varint(p.classes as u64);
        w.write_varint(self.n_trees as u64);
        w.write_varint(p.features.len() as u64);
        for f in &p.features {
            match f.levels {
                None => w.write_bits(0, 8),
                Some(l) => {
                    w.write_bits(1, 8);
                    w.write_varint(l as u64);
                }
            }
            w.write_varint(f.name.len() as u64);
            for &b in f.name.as_bytes() {
                w.write_byte(b);
            }
        }
        w.write_bits(
            match p.fit_codec {
                FitCodec::Huffman => 0,
                FitCodec::Arith => 1,
                FitCodec::Raw64 => 2,
            },
            8,
        );
        write_conditioning(&mut w, p.conditioning);
        if version == VERSION_CHAINED {
            p.chains.write(&mut w);
        }
        w.align_byte();
        sizes.header = w.bit_len() / 8;

        // ---- TABLES ----
        let mark = w.bit_len();
        for (f, a) in p.alphabets.splits.iter().enumerate() {
            match a {
                SplitAlphabet::Numeric(_)
                    if p.indexed_splits.get(f).is_some_and(|x| x.is_some()) =>
                {
                    // dataset-indexed (paper mode): sorted ranks of the used
                    // thresholds within the feature column's unique values;
                    // delta-gamma coding makes this a few bits per entry
                    let ranks = p.indexed_splits[f].as_ref().unwrap();
                    w.write_bits(2, 8);
                    w.write_varint(ranks.len() as u64);
                    let mut prev = 0u64;
                    for (i, &rank) in ranks.iter().enumerate() {
                        if i == 0 {
                            w.write_gamma(rank + 1);
                        } else {
                            debug_assert!(rank > prev, "ranks must be strictly increasing");
                            w.write_gamma(rank - prev);
                        }
                        prev = rank;
                    }
                }
                SplitAlphabet::Numeric(vals) if !p.chains.split_tables.is_empty() => {
                    // chain-coded numeric split table (kind 3)
                    w.write_bits(3, 8);
                    let payload = stage::encode_f64_chain(&p.chains.split_tables, vals)
                        .with_context(|| format!("split table {f} chain"))?;
                    w.write_varint(payload.len() as u64);
                    w.align_byte();
                    w.write_bytes(&payload);
                }
                SplitAlphabet::Numeric(vals) => {
                    w.write_bits(0, 8);
                    f64pack::write_block(vals, &mut w).expect("f64 table");
                }
                SplitAlphabet::Categorical(masks) => {
                    w.write_bits(1, 8);
                    w.write_varint(masks.len() as u64);
                    for m in masks {
                        w.write_varint(*m);
                    }
                }
            }
        }
        w.align_byte();
        sizes.split_value_tables = (w.bit_len() - mark) / 8;

        let mark = w.bit_len();
        // Raw64 fits live inline in the FITS payload; the table is written
        // empty (write_block(&[]) is what the owned-builder encoder emitted
        // after clearing the clone's fits, so the bytes are unchanged)
        let fit_vals: &[f64] =
            if p.fit_codec == FitCodec::Raw64 { &[] } else { &p.alphabets.fits };
        if p.chains.fit_table.is_empty() {
            f64pack::write_block(fit_vals, &mut w).expect("fit table");
        } else {
            // chain-coded fit value table (possibly lossy, regression only)
            let payload = stage::encode_f64_chain(&p.chains.fit_table, fit_vals)
                .context("fit table chain")?;
            w.write_varint(payload.len() as u64);
            w.align_byte();
            w.write_bytes(&payload);
        }
        w.align_byte();
        sizes.fit_value_table = (w.bit_len() - mark) / 8;

        // ---- CLUSMAP ----
        let mark = w.bit_len();
        write_map(&mut w, &p.vn_map);
        w.write_varint(p.split_maps.len() as u64);
        for m in &p.split_maps {
            write_map(&mut w, m);
        }
        write_map(&mut w, &p.fit_map);
        w.align_byte();
        sizes.cluster_maps = (w.bit_len() - mark) / 8;

        // ---- DICTS ----
        let mark = w.bit_len();
        w.write_varint(p.vn_dicts.len() as u64);
        for d in &p.vn_dicts {
            d.write_dict(&mut w);
        }
        w.write_varint(p.split_dicts.len() as u64);
        for per_feature in &p.split_dicts {
            w.write_varint(per_feature.len() as u64);
            for d in per_feature {
                d.write_dict(&mut w);
            }
        }
        w.write_varint(p.fit_dicts.len() as u64);
        for d in &p.fit_dicts {
            d.write_dict(&mut w);
        }
        w.write_varint(p.fit_models.len() as u64);
        for m in &p.fit_models {
            m.write(&mut w);
        }
        match &p.fit_raw_codec {
            Some(codec) => {
                w.write_bit(true);
                codec.write_dict(&mut w);
            }
            None => w.write_bit(false),
        }
        w.align_byte();
        sizes.dictionaries = (w.bit_len() - mark) / 8;

        // ---- STRUCT ----
        let mark = w.bit_len();
        w.write_varint(self.struct_bytes.len() as u64);
        w.align_byte();
        for &b in &self.struct_bytes {
            w.write_byte(b);
        }
        sizes.structure = (w.bit_len() - mark) / 8;

        // ---- VARS / SPLITS / FITS ----
        let mark = w.bit_len();
        write_payload_section(&mut w, &self.vars_trees);
        sizes.var_names = (w.bit_len() - mark) / 8;

        let mark = w.bit_len();
        write_payload_section(&mut w, &self.splits_trees);
        sizes.split_values = (w.bit_len() - mark) / 8;

        let mark = w.bit_len();
        write_payload_section(&mut w, &self.fits_trees);
        sizes.fits = (w.bit_len() - mark) / 8;

        Ok((w.into_bytes(), sizes))
    }
}

// ---------------------------------------------------------------- parsing

/// Parse a container from a borrowed buffer. Copies the bytes **once** into
/// a shared `Arc<[u8]>` and delegates to [`parse_arc`]; callers that already
/// hold an `Arc` (the model store, [`crate::compress::CompressedForest`]) or
/// an [`crate::util::mmap::Mmap`] should call [`parse_arc`] directly for a
/// fully zero-copy parse.
pub fn parse(bytes: &[u8]) -> Result<ParsedContainer> {
    parse_arc(Arc::<[u8]>::from(bytes))
}

/// Parse a shared container buffer — an `Arc<[u8]>` or a memory map, via
/// [`SharedBytes`] — with full validation; payload sections are recorded as
/// spans into `buf`, never copied.
pub fn parse_arc(buf: impl Into<SharedBytes>) -> Result<ParsedContainer> {
    parse_with_shared(buf.into(), None)
}

/// Parse a **pack member** whose side-information span (TABLES + CLUSMAP +
/// DICTS) was excised into a pack-level shared blob ([`crate::pack`]): the
/// member buffer holds `header ++ struct ++ payloads` contiguously and
/// `shared` holds exactly the excised bytes. The payload sections stay
/// zero-copy spans into `buf` (one mmap of a pack serves every member); the
/// side information — decoded into owned tables in any parse — is read from
/// the shared blob instead.
///
/// `sizes.total()` reports the *logical* container size (member + blob), the
/// size the reconstructed standalone `RFCZ` file would have.
pub fn parse_packed(buf: impl Into<SharedBytes>, shared: &[u8]) -> Result<ParsedContainer> {
    parse_with_shared(buf.into(), Some(shared))
}

/// Header fields (everything before the TABLES section).
struct ParsedHeader {
    classification: bool,
    classes: u32,
    n_trees: usize,
    features: Vec<FeatureMeta>,
    fit_codec: FitCodec,
    conditioning: ModelConditioning,
    chains: SectionChains,
    header_bytes: u64,
}

/// The decode side information: TABLES + CLUSMAP + DICTS, plus the byte
/// size of each (the middle of [`SectionSizes`]).
struct ParsedSideInfo {
    alphabets: ValueAlphabets,
    indexed_splits: Vec<Option<Vec<u64>>>,
    vn_map: BTreeMap<ContextKey, u32>,
    split_maps: Vec<BTreeMap<ContextKey, u32>>,
    fit_map: BTreeMap<ContextKey, u32>,
    vn_dicts: Vec<HuffmanCode>,
    split_dicts: Vec<Vec<HuffmanCode>>,
    fit_dicts: Vec<HuffmanCode>,
    fit_models: Vec<FreqModel>,
    fit_raw_codec: Option<F64Codec>,
    split_value_tables: u64,
    fit_value_table: u64,
    cluster_maps: u64,
    dictionaries: u64,
}

/// STRUCT + the three payload sections (spans relative to the member buffer).
struct ParsedTail {
    zaks_bits: Vec<bool>,
    vars_ranges: Vec<(usize, usize)>,
    splits_ranges: Vec<(usize, usize)>,
    fits_ranges: Vec<(usize, usize)>,
    vars_span: (usize, usize),
    splits_span: (usize, usize),
    fits_span: (usize, usize),
    structure: u64,
    var_names: u64,
    split_values: u64,
    fits: u64,
}

fn read_header(r: &mut BitReader) -> Result<ParsedHeader> {
    let mut magic = [0u8; 4];
    for m in magic.iter_mut() {
        *m = r.read_byte().context("magic")?;
    }
    if &magic != MAGIC {
        bail!("not an RFCZ container (bad magic)");
    }
    let version = r.read_bits(8).context("version")? as u8;
    if version != VERSION && version != VERSION_CHAINED {
        bail!("unsupported container version {version}");
    }
    let classification = r.read_bits(8).context("kind")? != 0;
    let classes = r.read_varint().context("classes")? as u32;
    let n_trees_raw = r.read_varint().context("n_trees")?;
    if n_trees_raw == 0 || n_trees_raw > 50_000_000 {
        bail!("implausible tree count {n_trees_raw}");
    }
    let n_trees = cast_usize(n_trees_raw, "tree count")?;
    let d_raw = r.read_varint().context("features")?;
    if d_raw == 0 || d_raw > 10_000_000 {
        bail!("implausible feature count {d_raw}");
    }
    let d = cast_usize(d_raw, "feature count")?;
    let mut features = Vec::with_capacity(d);
    for _ in 0..d {
        let kind = r.read_bits(8).context("feature kind")?;
        let levels = match kind {
            0 => None,
            1 => Some(r.read_varint().context("levels")? as u32),
            v => bail!("unknown feature kind {v}"),
        };
        let name_len_raw = r.read_varint().context("name len")?;
        if name_len_raw > 4096 {
            bail!("implausible feature name length");
        }
        let name_len = cast_usize(name_len_raw, "feature name length")?;
        let mut name_bytes = Vec::with_capacity(name_len);
        for _ in 0..name_len {
            name_bytes.push(r.read_byte().context("name")?);
        }
        features.push(FeatureMeta {
            name: String::from_utf8(name_bytes).context("feature name utf8")?,
            levels,
        });
    }
    let fit_codec = match r.read_bits(8).context("fit codec")? {
        0 => FitCodec::Huffman,
        1 => FitCodec::Arith,
        2 => FitCodec::Raw64,
        v => bail!("unknown fit codec {v}"),
    };
    let conditioning = read_conditioning(r)?;
    let chains = if version == VERSION_CHAINED {
        let c = SectionChains::read(r).context("container chains")?;
        // validated on read so a corrupt header (e.g. zero-width column
        // split, misplaced lossy stage) fails here, not mid-decode
        c.validate(classification).context("container chains")?;
        c
    } else {
        SectionChains::default()
    };
    r.align_byte();
    Ok(ParsedHeader {
        classification,
        classes,
        n_trees,
        features,
        fit_codec,
        conditioning,
        chains,
        header_bytes: r.bit_pos() / 8,
    })
}

fn read_side_info(r: &mut BitReader, h: &ParsedHeader) -> Result<ParsedSideInfo> {
    let d = h.features.len();

    // ---- TABLES ----
    let mark = r.bit_pos();
    let mut splits = Vec::with_capacity(d);
    let mut indexed_splits = vec![None; d];
    for f in 0..d {
        let kind = r.read_bits(8).context("table kind")?;
        match kind {
            0 => {
                if h.features[f].levels.is_some() {
                    bail!("numeric table for categorical feature {f}");
                }
                let vals =
                    f64pack::read_block(r).with_context(|| format!("split table {f}"))?;
                splits.push(SplitAlphabet::Numeric(vals));
            }
            2 => {
                if h.features[f].levels.is_some() {
                    bail!("numeric table for categorical feature {f}");
                }
                let n_raw = r.read_varint().context("indexed table len")?;
                if n_raw > 500_000_000 {
                    bail!("implausible indexed alphabet size");
                }
                let n = cast_usize(n_raw, "indexed alphabet size")?;
                let mut ranks = Vec::with_capacity(n);
                let mut prev = 0u64;
                for i in 0..n {
                    let g = r.read_gamma().context("indexed rank")?;
                    let rank = if i == 0 { g - 1 } else { prev + g };
                    ranks.push(rank);
                    prev = rank;
                }
                indexed_splits[f] = Some(ranks);
                splits.push(SplitAlphabet::Numeric(Vec::new()));
            }
            3 => {
                if h.features[f].levels.is_some() {
                    bail!("numeric table for categorical feature {f}");
                }
                if h.chains.split_tables.is_empty() {
                    bail!("chain-coded split table {f} in a chainless container");
                }
                let len_raw = r.read_varint().context("chained table len")?;
                if len_raw > (1u64 << 33) {
                    bail!("implausible chained table size {len_raw}");
                }
                let len = cast_usize(len_raw, "chained table size")?;
                r.align_byte();
                let mut payload = Vec::with_capacity(len.min(1 << 20));
                for _ in 0..len {
                    payload.push(r.read_byte().context("chained table bytes")?);
                }
                let vals = stage::decode_f64_chain(&h.chains.split_tables, &payload)
                    .with_context(|| format!("split table {f} chain"))?;
                splits.push(SplitAlphabet::Numeric(vals));
            }
            1 => {
                if h.features[f].levels.is_none() {
                    bail!("categorical table for numeric feature {f}");
                }
                let n_raw = r.read_varint().context("table len")?;
                if n_raw > 500_000_000 {
                    bail!("implausible alphabet size");
                }
                let n = cast_usize(n_raw, "alphabet size")?;
                let mut masks = Vec::with_capacity(n);
                for _ in 0..n {
                    masks.push(r.read_varint().context("table mask")?);
                }
                splits.push(SplitAlphabet::Categorical(masks));
            }
            v => bail!("unknown table kind {v}"),
        }
    }
    r.align_byte();
    let split_value_tables = (r.bit_pos() - mark) / 8;

    let mark = r.bit_pos();
    let fits = if h.chains.fit_table.is_empty() {
        f64pack::read_block(r).context("fit table")?
    } else {
        let len_raw = r.read_varint().context("chained fit table len")?;
        if len_raw > (1u64 << 33) {
            bail!("implausible chained fit table size {len_raw}");
        }
        let len = cast_usize(len_raw, "chained fit table size")?;
        r.align_byte();
        let mut payload = Vec::with_capacity(len.min(1 << 20));
        for _ in 0..len {
            payload.push(r.read_byte().context("chained fit table bytes")?);
        }
        stage::decode_f64_chain(&h.chains.fit_table, &payload).context("fit table chain")?
    };
    r.align_byte();
    let fit_value_table = (r.bit_pos() - mark) / 8;
    let alphabets = ValueAlphabets { splits, fits };

    // ---- CLUSMAP ----
    let mark = r.bit_pos();
    let vn_map = read_map(r)?;
    let n_split_maps = r.read_varint().context("split maps")?;
    if n_split_maps != d as u64 {
        bail!("split map count {n_split_maps} != features {d}");
    }
    let mut split_maps = Vec::with_capacity(d);
    for _ in 0..d {
        split_maps.push(read_map(r)?);
    }
    let fit_map = read_map(r)?;
    r.align_byte();
    let cluster_maps = (r.bit_pos() - mark) / 8;

    // ---- DICTS ----
    let mark = r.bit_pos();
    let n_vn = cast_usize(r.read_varint().context("vn dicts")?, "vn dict count")?;
    let mut vn_dicts = Vec::with_capacity(n_vn.min(1 << 20));
    for _ in 0..n_vn {
        vn_dicts.push(HuffmanCode::read_dict(r)?);
    }
    let n_sd = r.read_varint().context("split dicts")?;
    if n_sd != d as u64 {
        bail!("split dict group count mismatch");
    }
    let mut split_dicts = Vec::with_capacity(d);
    for _ in 0..d {
        let k = cast_usize(r.read_varint().context("split dict k")?, "split dict count")?;
        let mut per = Vec::with_capacity(k.min(1 << 20));
        for _ in 0..k {
            per.push(HuffmanCode::read_dict(r)?);
        }
        split_dicts.push(per);
    }
    let n_fd = cast_usize(r.read_varint().context("fit dicts")?, "fit dict count")?;
    let mut fit_dicts = Vec::with_capacity(n_fd.min(1 << 20));
    for _ in 0..n_fd {
        fit_dicts.push(HuffmanCode::read_dict(r)?);
    }
    let n_fm = cast_usize(r.read_varint().context("fit models")?, "fit model count")?;
    let mut fit_models = Vec::with_capacity(n_fm.min(1 << 20));
    for _ in 0..n_fm {
        fit_models.push(FreqModel::read(r)?);
    }
    let fit_raw_codec = if r.read_bit().context("raw codec flag")? {
        Some(F64Codec::read_dict(r)?)
    } else {
        None
    };
    if (h.fit_codec == FitCodec::Raw64) != fit_raw_codec.is_some() {
        bail!("raw fit codec presence disagrees with fit codec");
    }
    r.align_byte();
    let dictionaries = (r.bit_pos() - mark) / 8;

    Ok(ParsedSideInfo {
        alphabets,
        indexed_splits,
        vn_map,
        split_maps,
        fit_map,
        vn_dicts,
        split_dicts,
        fit_dicts,
        fit_models,
        fit_raw_codec,
        split_value_tables,
        fit_value_table,
        cluster_maps,
        dictionaries,
    })
}

fn read_tail(r: &mut BitReader, bytes: &[u8], h: &ParsedHeader) -> Result<ParsedTail> {
    let n_trees = h.n_trees;
    // ---- STRUCT ----
    let mark = r.bit_pos();
    let sb_len_raw = r.read_varint().context("struct len")?;
    if sb_len_raw > (1u64 << 33) {
        bail!("implausible struct size");
    }
    let sb_len = cast_usize(sb_len_raw, "struct size")?;
    r.align_byte();
    let sb_start = cast_usize(r.bit_pos() / 8, "struct offset")?;
    let sb_end = sb_start.checked_add(sb_len).context("struct span overflow")?;
    if sb_end > bytes.len() {
        bail!("structure section truncated");
    }
    let struct_bytes = &bytes[sb_start..sb_end];
    r.seek_bits(sb_end as u64 * 8);
    let structure = (r.bit_pos() - mark) / 8;

    // decode structure: 1-byte mode prefix (0 = LZSS, 1 = raw packed,
    // 2 = stage-chain coded per the header's structure chain)
    if struct_bytes.is_empty() {
        bail!("empty structure section");
    }
    let lz_owned;
    let chain_owned;
    let packed: &[u8] = match struct_bytes[0] {
        0 => {
            lz_owned = crate::coding::lz::decompress_from_bytes(&struct_bytes[1..])
                .context("structure LZ stream")?;
            &lz_owned
        }
        1 => &struct_bytes[1..],
        2 => {
            if h.chains.structure.is_empty() {
                bail!("chain-coded structure in a chainless container");
            }
            chain_owned = stage::decode_chain(&h.chains.structure, &struct_bytes[1..])
                .context("structure chain")?
                .into_single()
                .context("structure chain")?;
            &chain_owned
        }
        v => bail!("unknown structure mode {v}"),
    };
    // the packed stream carries total bit count as a varint prefix
    let mut zr = BitReader::new(packed);
    let total_bits = zr.read_varint().context("zaks bit count")?;
    if total_bits > packed.len() as u64 * 8 {
        bail!("zaks bit count {total_bits} exceeds the packed stream");
    }
    let mut zaks_bits = Vec::with_capacity(total_bits as usize);
    for _ in 0..total_bits {
        zaks_bits.push(zr.read_bit().context("zaks bits")?);
    }

    // ---- VARS / SPLITS / FITS ----
    let mark = r.bit_pos();
    let (vars_ranges, vars_span) = read_payload_spans(r, bytes.len())?;
    let var_names = (r.bit_pos() - mark) / 8;
    let mark = r.bit_pos();
    let (splits_ranges, splits_span) = read_payload_spans(r, bytes.len())?;
    let split_values = (r.bit_pos() - mark) / 8;
    let mark = r.bit_pos();
    let (fits_ranges, fits_span) = read_payload_spans(r, bytes.len())?;
    let fits = (r.bit_pos() - mark) / 8;

    if vars_ranges.len() != n_trees
        || splits_ranges.len() != n_trees
        || fits_ranges.len() != n_trees
    {
        bail!("payload tree counts disagree with header");
    }

    Ok(ParsedTail {
        zaks_bits,
        vars_ranges,
        splits_ranges,
        fits_ranges,
        vars_span,
        splits_span,
        fits_span,
        structure,
        var_names,
        split_values,
        fits,
    })
}

/// The shared parse core. With `shared: None` the side information is read
/// from `buf` in place (a plain standalone container); with `Some(blob)` it
/// is read from the blob and `buf` must hold `header ++ struct ++ payloads`
/// (a pack member). The blob must be consumed exactly — leftover bytes mean
/// the member and the blob disagree about the format.
fn parse_with_shared(buf: SharedBytes, shared: Option<&[u8]>) -> Result<ParsedContainer> {
    let (h, side, tail) = {
        let bytes: &[u8] = &buf;
        let mut r = BitReader::new(bytes);
        let h = read_header(&mut r)?;
        let side = match shared {
            None => read_side_info(&mut r, &h)?,
            Some(blob) => {
                let mut rs = BitReader::new(blob);
                let side = read_side_info(&mut rs, &h)
                    .context("shared side-information blob")?;
                let consumed = rs.bit_pos() / 8;
                if consumed != blob.len() as u64 {
                    bail!(
                        "shared side-information blob mismatch: consumed {consumed} of {} bytes",
                        blob.len()
                    );
                }
                side
            }
        };
        let tail = read_tail(&mut r, bytes, &h)?;
        (h, side, tail)
    };

    let sizes = SectionSizes {
        header: h.header_bytes,
        split_value_tables: side.split_value_tables,
        fit_value_table: side.fit_value_table,
        cluster_maps: side.cluster_maps,
        dictionaries: side.dictionaries,
        structure: tail.structure,
        var_names: tail.var_names,
        split_values: tail.split_values,
        fits: tail.fits,
    };
    Ok(ParsedContainer {
        classification: h.classification,
        classes: h.classes,
        n_trees: h.n_trees,
        features: h.features,
        fit_codec: h.fit_codec,
        conditioning: h.conditioning,
        chains: h.chains,
        alphabets: side.alphabets,
        indexed_splits: side.indexed_splits,
        vn_map: side.vn_map,
        split_maps: side.split_maps,
        fit_map: side.fit_map,
        vn_dicts: side.vn_dicts,
        split_dicts: side.split_dicts,
        fit_dicts: side.fit_dicts,
        fit_models: side.fit_models,
        fit_raw_codec: side.fit_raw_codec,
        zaks_bits: tail.zaks_bits,
        vars_ranges: tail.vars_ranges,
        splits_ranges: tail.splits_ranges,
        fits_ranges: tail.fits_ranges,
        buf,
        plan_id: NEXT_PLAN_ID.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
        vars_span: tail.vars_span,
        splits_span: tail.splits_span,
        fits_span: tail.fits_span,
        sizes,
    })
}

/// Pack a bit vector with a varint bit-count prefix (the STRUCT pre-LZ form).
pub fn pack_bits(bits: &[bool]) -> Vec<u8> {
    let mut w = BitWriter::new();
    w.write_varint(bits.len() as u64);
    for &b in bits {
        w.write_bit(b);
    }
    w.into_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_bits_roundtrip() {
        let bits = vec![true, false, true, true, false];
        let packed = pack_bits(&bits);
        let mut r = BitReader::new(&packed);
        let n = r.read_varint().unwrap();
        assert_eq!(n, 5);
        let out: Vec<bool> = (0..n).map(|_| r.read_bit().unwrap()).collect();
        assert_eq!(out, bits);
    }

    #[test]
    fn bad_magic_rejected() {
        assert!(parse(b"NOPE....").is_err());
        assert!(parse(b"").is_err());
    }

    #[test]
    fn map_roundtrip_with_root_father() {
        let mut map = BTreeMap::new();
        map.insert(ContextKey { depth: 0, father: ROOT_FATHER }, 0u32);
        map.insert(ContextKey { depth: 3, father: 7 }, 2u32);
        let mut w = BitWriter::new();
        write_map(&mut w, &map);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(read_map(&mut r).unwrap(), map);
    }

    #[test]
    fn payload_section_roundtrip() {
        let trees = vec![vec![1u8, 2, 3], vec![], vec![42u8; 10]];
        let mut w = BitWriter::new();
        write_payload_section(&mut w, &trees);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        let (ranges, span) = read_payload_spans(&mut r, bytes.len()).unwrap();
        let payload = &bytes[span.0..span.1];
        assert_eq!(ranges.len(), 3);
        assert_eq!(&payload[ranges[0].0..ranges[0].1], &[1, 2, 3]);
        assert_eq!(ranges[1].0, ranges[1].1);
        assert_eq!(&payload[ranges[2].0..ranges[2].1], &[42u8; 10][..]);
        // the span must cover exactly the payload tail of the section
        assert_eq!(span.1 - span.0, 13);
        assert_eq!(span.1, bytes.len());
    }

    #[test]
    fn truncated_payload_section_errors() {
        let trees = vec![vec![7u8; 64]];
        let mut w = BitWriter::new();
        write_payload_section(&mut w, &trees);
        let bytes = w.into_bytes();
        let cut = &bytes[..bytes.len() - 8];
        let mut r = BitReader::new(cut);
        assert!(read_payload_spans(&mut r, cut.len()).is_err());
    }

    #[test]
    fn zero_copy_sections_share_the_buffer() {
        use crate::compress::pipeline::{CompressOptions, CompressedForest};
        use crate::data::synthetic;
        use crate::forest::{Forest, ForestParams};
        let ds = synthetic::iris(99);
        let f = Forest::train(&ds, &ForestParams::classification(4), 9);
        let cf = CompressedForest::compress(&f, &ds, &CompressOptions::default()).unwrap();
        let buf: Arc<[u8]> = cf.bytes.clone();
        let pc = parse_arc(buf.clone()).unwrap();
        // the parse holds the very same allocation...
        assert_eq!(pc.buffer().as_ptr(), buf.as_ptr(), "parse must not copy the buffer");
        assert!(!pc.buffer().is_mapped(), "a heap Arc parses as the Heap variant");
        // ...and every payload section is a pointer into it (no per-section
        // copies) — the zero-copy acceptance check
        let base = buf.as_ptr() as usize;
        for (name, sect) in [
            ("vars", pc.vars_bytes()),
            ("splits", pc.splits_bytes()),
            ("fits", pc.fits_bytes()),
        ] {
            let p = sect.as_ptr() as usize;
            assert!(
                p >= base && p + sect.len() <= base + buf.len(),
                "{name} section must alias the shared buffer"
            );
        }
        // per-tree slices alias the same allocation too
        for t in 0..pc.n_trees {
            let p = pc.tree_fits(t).as_ptr() as usize;
            assert!(p >= base && p + pc.tree_fits(t).len() <= base + buf.len());
        }
        // and a second parse of the same Arc shares it as well (two
        // predictors, one resident buffer)
        let pc2 = parse_arc(buf.clone()).unwrap();
        assert_eq!(pc2.buffer().as_ptr(), pc.buffer().as_ptr());
    }

    #[test]
    fn mapped_parse_is_zero_copy_into_the_mapping() {
        // the tiered store's reload path: container bytes on disk, parsed
        // through an mmap-backed SharedBytes — every payload section must
        // alias the mapped region (no decode, no payload memcpy)
        use crate::compress::pipeline::{CompressOptions, CompressedForest};
        use crate::data::synthetic;
        use crate::forest::{Forest, ForestParams};
        let ds = synthetic::iris(98);
        let f = Forest::train(&ds, &ForestParams::classification(4), 10);
        let cf = CompressedForest::compress(&f, &ds, &CompressOptions::default()).unwrap();
        let path = std::env::temp_dir()
            .join(format!("rfc-container-mmap-test-{}.rfcz", std::process::id()));
        std::fs::write(&path, &cf.bytes).unwrap();

        let map = crate::util::mmap::Mmap::map_path(&path).unwrap();
        let base = map.as_slice().as_ptr() as usize;
        let len = map.len();
        assert_eq!(len as u64, cf.total_bytes());
        let pc = parse_arc(map).unwrap();
        #[cfg(all(unix, target_pointer_width = "64"))]
        assert!(pc.buffer().is_mapped(), "reload parses must ride the mapping");
        assert_eq!(pc.buffer().as_ptr() as usize, base);
        for (name, sect) in [
            ("vars", pc.vars_bytes()),
            ("splits", pc.splits_bytes()),
            ("fits", pc.fits_bytes()),
        ] {
            let p = sect.as_ptr() as usize;
            assert!(
                p >= base && p + sect.len() <= base + len,
                "{name} section must alias the mapped file"
            );
        }
        // the mapped parse decodes identically to the heap parse
        let heap = parse_arc(cf.bytes.clone()).unwrap();
        assert_eq!(pc.n_trees, heap.n_trees);
        assert_eq!(pc.zaks_bits, heap.zaks_bits);
        for t in 0..pc.n_trees {
            assert_eq!(pc.tree_vars(t), heap.tree_vars(t), "tree {t} vars");
            assert_eq!(pc.tree_splits(t), heap.tree_splits(t), "tree {t} splits");
            assert_eq!(pc.tree_fits(t), heap.tree_fits(t), "tree {t} fits");
        }
        // fresh plan ids per parse: mapped and heap parses never share plans
        assert_ne!(pc.plan_id(), heap.plan_id());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn view_aliases_its_base_and_composes() {
        let backing: Arc<[u8]> = (0u8..64).collect::<Vec<u8>>().into();
        let sb = SharedBytes::from(backing.clone());
        let v = sb.slice(8, 32).unwrap();
        assert_eq!(v.len(), 32);
        assert_eq!(v.as_ptr() as usize, backing.as_ptr() as usize + 8, "view must alias");
        assert_eq!(&v[..4], &[8, 9, 10, 11]);
        // a view of a view collapses onto the root buffer
        let vv = v.slice(4, 8).unwrap();
        assert_eq!(vv.as_ptr() as usize, backing.as_ptr() as usize + 12);
        assert!(matches!(&vv, SharedBytes::View { base, .. } if matches!(**base, SharedBytes::Heap(_))));
        assert!(!vv.is_mapped());
        // out-of-bounds views are rejected, never mis-sliced
        assert!(sb.slice(60, 8).is_err());
        assert!(v.slice(30, 4).is_err());
        assert!(sb.slice(usize::MAX, 2).is_err(), "offset+len overflow must error");
    }

    #[test]
    fn parse_packed_reconstitutes_an_excised_member() {
        // split a standalone container at its side-info span and parse the
        // member (header ++ struct ++ payloads) against the excised blob:
        // every decoded field must match the plain parse
        use crate::compress::pipeline::{CompressOptions, CompressedForest};
        use crate::data::synthetic;
        use crate::forest::{Forest, ForestParams};
        let ds = synthetic::iris(77);
        let f = Forest::train(&ds, &ForestParams::classification(4), 78);
        let cf = CompressedForest::compress(&f, &ds, &CompressOptions::default()).unwrap();
        let plain = parse_arc(cf.bytes.clone()).unwrap();
        let (s, e) = plain.side_info_span();
        let blob = cf.bytes[s..e].to_vec();
        let mut member = cf.bytes[..s].to_vec();
        member.extend_from_slice(&cf.bytes[e..]);

        let member: Arc<[u8]> = member.into();
        let pc = parse_packed(member.clone(), &blob).unwrap();
        assert_eq!(pc.n_trees, plain.n_trees);
        assert_eq!(pc.features, plain.features);
        assert_eq!(pc.zaks_bits, plain.zaks_bits);
        assert_eq!(pc.vn_map, plain.vn_map);
        assert_eq!(pc.vn_dicts, plain.vn_dicts);
        for t in 0..pc.n_trees {
            assert_eq!(pc.tree_vars(t), plain.tree_vars(t), "tree {t} vars");
            assert_eq!(pc.tree_splits(t), plain.tree_splits(t), "tree {t} splits");
            assert_eq!(pc.tree_fits(t), plain.tree_fits(t), "tree {t} fits");
        }
        // sizes report the LOGICAL container (member + blob)
        assert_eq!(pc.sizes, plain.sizes);
        assert_eq!(pc.sizes.total() as usize, member.len() + blob.len());
        // payload sections are zero-copy spans into the member buffer
        let base = member.as_ptr() as usize;
        for sect in [pc.vars_bytes(), pc.splits_bytes(), pc.fits_bytes()] {
            let p = sect.as_ptr() as usize;
            assert!(p >= base && p + sect.len() <= base + member.len());
        }
        // the packed parse decodes to the identical forest
        let g = crate::compress::pipeline::decompress_container(&pc).unwrap();
        assert!(g.identical(&f));
        // a wrong / truncated blob is a typed error, not a mis-parse
        assert!(parse_packed(member.clone(), &blob[..blob.len() - 1]).is_err());
        let mut long = blob.clone();
        long.push(0);
        assert!(parse_packed(member, &long).is_err(), "trailing blob bytes must error");
    }

    #[test]
    fn legacy_v1_containers_parse_unchanged() {
        // default options emit a chainless version-1 container — the exact
        // wire format of the pre-stage-pipeline encoder — and the parse
        // reports empty chains and decodes to the identical forest
        use crate::compress::pipeline::{CompressOptions, CompressedForest};
        use crate::data::synthetic;
        use crate::forest::{Forest, ForestParams};
        let ds = synthetic::iris(55);
        let f = Forest::train(&ds, &ForestParams::classification(4), 7);
        let cf = CompressedForest::compress(&f, &ds, &CompressOptions::default()).unwrap();
        assert_eq!(cf.bytes[4], VERSION, "chainless containers must stay version 1");
        let pc = parse_arc(cf.bytes.clone()).unwrap();
        assert!(pc.chains.is_default(), "v1 parses with empty chains");
        let g = crate::compress::pipeline::decompress_container(&pc).unwrap();
        assert!(g.identical(&f));
    }

    #[test]
    fn oversized_counts_error_before_any_cast() {
        // a header claiming u64::MAX trees must surface a typed error on
        // every platform (plausibility cap on 64-bit, checked cast on
        // 32-bit) — never a silent truncation
        let mut w = BitWriter::new();
        for &b in MAGIC {
            w.write_byte(b);
        }
        w.write_bits(VERSION as u64, 8);
        w.write_bits(1, 8); // classification
        w.write_varint(2); // classes
        w.write_varint(u64::MAX); // n_trees
        let bytes = w.into_bytes();
        let err = parse(&bytes).unwrap_err().to_string();
        assert!(
            err.contains("implausible") || err.contains("usize"),
            "typed error expected, got: {err}"
        );
    }
}
