//! Algorithm 1 end-to-end: compress (extract → cluster → encode) and the
//! inverse full decompression.
//!
//! Compression stages (paper §4):
//!
//! 1. **Structure** — concatenate per-tree Zaks sequences, LZSS-encode
//!    (lines 1–3).
//! 2. **Models** — extract the conditional count tables (lines 4–21) via
//!    [`crate::model::extract`].
//! 3. **Clustering** — K-sweep of eq. (6) per model family: one sweep for
//!    variable names, one per feature for split values, one for fits
//!    (lines 22–30 / 39 / 40), through a pluggable [`LloydEngine`] (native
//!    or the AOT-compiled XLA artifact).
//! 4. **Encoding** — per tree, per node in preorder: Huffman-encode the
//!    variable name and split rank against their context's cluster codebook;
//!    fits go through Huffman or (two-class) arithmetic coding
//!    (lines 31–38).
//!
//! Decompression runs the stages backwards; it needs nothing but the
//! container bytes.

use super::container::{self, ContainerBuilder, FeatureMeta, FitCodec, ParsedContainer, SectionSizes};
use crate::cluster::kmeans::{LloydEngine, NativeEngine};
use crate::cluster::sweep::{assignment_map, cluster_counts, sweep_k};
use crate::coding::arith::{ArithDecoder, ArithEncoder, FreqModel};
use crate::coding::bitio::{BitReader, BitWriter};
use crate::coding::entropy::DictCost;
use crate::coding::f64pack::F64Codec;
use crate::coding::huffman::{HuffmanCode, HuffmanDecoder};
use crate::coding::stage::{self, SectionChains};
use crate::data::{Column, Dataset};
use crate::forest::{Fit, Forest, Node, Split, Tree};
use crate::model::extract::{CountTable, ForestModels, SplitAlphabet, ValueAlphabets};
use crate::model::keys::{ContextKey, ModelConditioning};
use crate::zaks;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Compression options.
#[derive(Debug, Clone)]
pub struct CompressOptions {
    /// Largest K tried in each clustering sweep.
    pub k_max: usize,
    /// Clustering seed (deterministic output for a given forest + options).
    pub seed: u64,
    /// Worker threads for extraction/encoding.
    pub workers: usize,
    /// Model conditioning (paper default: depth + father's variable name).
    pub conditioning: ModelConditioning,
    /// Fit representation bits used in the dictionary-cost α (the paper's
    /// 64-bit "orthodox losslessness"; 32 reproduces the ~7-cluster
    /// observation of §6). Does **not** quantize anything — see
    /// [`crate::lossy`] for actual quantization.
    pub fit_alpha_bits: u32,
    /// Paper mode (§3.2.2): store numeric split thresholds as observation
    /// ranks instead of f64 tables. Decoding then needs the training
    /// dataset ([`CompressedForest::decompress_with_dataset`]); the
    /// container shrinks by the whole value-table cost — this is how the
    /// paper's Table 1/2 account sizes. Default off (self-contained).
    pub dataset_indexed_splits: bool,
    /// Per-section transform-stage chains ([`crate::coding::stage`]).
    /// Empty chains (the default) reproduce the fixed four-stage pipeline
    /// byte-for-byte as a version-1 container; non-empty chains are
    /// recorded in a version-2 header. A lossy convert stage is only legal
    /// at the head of the fit chain on regression forests (§5); use
    /// `repro sweep-stages` to search chains per dataset.
    pub chains: SectionChains,
}

impl Default for CompressOptions {
    fn default() -> Self {
        CompressOptions {
            k_max: 10,
            seed: 0x5eed,
            workers: 1,
            conditioning: ModelConditioning::DepthFather,
            fit_alpha_bits: 64,
            dataset_indexed_splits: false,
            chains: SectionChains::default(),
        }
    }
}

/// A compressed forest: the container bytes plus the size breakdown and the
/// clustering diagnostics the benches report.
///
/// The bytes live in an `Arc<[u8]>` so that parsing ([`Self::parse`]) and
/// every predictor built on top share the single buffer — cloning a
/// `CompressedForest` or spinning up N predictors never duplicates the
/// container.
#[derive(Debug, Clone)]
pub struct CompressedForest {
    /// The complete `RFCZ` container bytes.
    pub bytes: std::sync::Arc<[u8]>,
    /// Per-section byte accounting.
    pub sizes: SectionSizes,
    /// (family label, chosen K) per clustering sweep, for §6-style analysis.
    pub cluster_ks: Vec<(String, usize)>,
}

impl CompressedForest {
    /// Compress with the native clustering engine.
    pub fn compress(forest: &Forest, ds: &Dataset, opts: &CompressOptions) -> Result<Self> {
        Self::compress_with_engine(forest, ds, opts, &mut NativeEngine)
    }

    /// Compress with an explicit [`LloydEngine`] (the XLA runtime engine in
    /// production, the native one in tests).
    pub fn compress_with_engine(
        forest: &Forest,
        ds: &Dataset,
        opts: &CompressOptions,
        engine: &mut dyn LloydEngine,
    ) -> Result<Self> {
        let plan = build_codec_plan(forest, ds, opts, engine)?;
        encode_with_plan(forest, &plan, opts.workers)
    }

    /// Total compressed size in bytes.
    pub fn total_bytes(&self) -> u64 {
        self.bytes.len() as u64
    }

    /// Parse the container (validates everything up front). Zero-copy: the
    /// parse shares this forest's `Arc<[u8]>` buffer.
    pub fn parse(&self) -> Result<ParsedContainer> {
        container::parse_arc(self.bytes.clone())
    }

    /// Full decompression: rebuild the forest bit-exactly. Errors when the
    /// container was built in dataset-indexed mode (use
    /// [`Self::decompress_with_dataset`]).
    pub fn decompress(&self) -> Result<Forest> {
        let pc = self.parse()?;
        if pc.needs_dataset() {
            bail!(
                "container uses dataset-indexed split coding (paper mode); \
                 call decompress_with_dataset(&training_data)"
            );
        }
        decompress_container(&pc)
    }

    /// Decompress a dataset-indexed container (paper mode): the training
    /// data regenerates the numeric split-value tables.
    pub fn decompress_with_dataset(&self, ds: &Dataset) -> Result<Forest> {
        let mut pc = self.parse()?;
        pc.attach_dataset(ds)?;
        decompress_container(&pc)
    }

    /// Wrap existing container bytes (e.g. read from disk).
    pub fn from_bytes(bytes: impl Into<std::sync::Arc<[u8]>>) -> Result<Self> {
        let bytes: std::sync::Arc<[u8]> = bytes.into();
        let pc = container::parse_arc(bytes.clone())?;
        let sizes = pc.sizes;
        Ok(CompressedForest { bytes, sizes, cluster_ks: Vec::new() })
    }
}

/// Everything the per-tree encoder needs, independent of which trees it
/// encodes: shared value alphabets, cluster maps, and codebooks — stages
/// 2–3 of Algorithm 1 frozen into a reusable plan.
///
/// [`CompressedForest::compress_with_engine`] builds one plan per forest;
/// [`crate::pack::compress_cohort`] builds one plan per **cohort** (the
/// clustering runs across the union of every member's tree-model tables) and
/// encodes each member against it — which is what makes the members'
/// side-information sections byte-identical, and therefore dedupable into a
/// pack-level shared-codebook blob.
pub struct CodecPlan {
    pub(crate) classification: bool,
    pub(crate) classes: u32,
    pub(crate) features: Vec<FeatureMeta>,
    pub(crate) fit_codec: FitCodec,
    pub(crate) conditioning: ModelConditioning,
    pub(crate) alphabets: ValueAlphabets,
    pub(crate) indexed_splits: Vec<Option<Vec<u64>>>,
    pub(crate) vn_map: BTreeMap<ContextKey, u32>,
    pub(crate) split_maps: Vec<BTreeMap<ContextKey, u32>>,
    pub(crate) fit_map: BTreeMap<ContextKey, u32>,
    pub(crate) vn_dicts: Vec<HuffmanCode>,
    pub(crate) split_dicts: Vec<Vec<HuffmanCode>>,
    pub(crate) fit_dicts: Vec<HuffmanCode>,
    pub(crate) fit_models: Vec<FreqModel>,
    pub(crate) fit_raw_codec: Option<F64Codec>,
    pub(crate) chains: SectionChains,
    pub(crate) cluster_ks: Vec<(String, usize)>,
}

impl CodecPlan {
    /// The chosen K per clustering sweep (diagnostics).
    pub fn cluster_ks(&self) -> &[(String, usize)] {
        &self.cluster_ks
    }
}

/// Stages 2–3 of Algorithm 1: extract the conditional count tables from
/// `forest` (for a cohort: the **union** forest of every member's trees),
/// sweep the clustering per model family, pick the fit codec, and freeze the
/// result into a [`CodecPlan`] any subset of those trees can be encoded
/// against (losslessness only needs codebook support ⊇ member support, which
/// the union guarantees).
pub(crate) fn build_codec_plan(
    forest: &Forest,
    ds: &Dataset,
    opts: &CompressOptions,
    engine: &mut dyn LloydEngine,
) -> Result<CodecPlan> {
    if forest.trees.is_empty() {
        bail!("cannot compress an empty forest");
    }
    ds.validate()?;
    opts.chains
        .validate(forest.classification)
        .context("compression options stage chains")?;
    let d = ds.num_features();

    // ---- stage 2: models ----
    let alphabets = ValueAlphabets::collect(forest, ds)?;
    let models = ForestModels::extract(forest, &alphabets, opts.conditioning, opts.workers);

    // ---- stage 3: clustering ----
    let mut cluster_ks = Vec::new();

    // variable names
    let (vn_map, vn_counts) = cluster_family(
        &models.var_names,
        DictCost::variable_names(d),
        opts.k_max,
        opts.seed,
        engine,
    )?;
    cluster_ks.push(("var_names".to_string(), vn_counts.len().max(1)));
    let vn_dicts: Vec<HuffmanCode> = vn_counts
        .iter()
        .map(|c| huffman_from_counts(c))
        .collect::<Result<_>>()?;

    // split values, per feature
    let n_obs = ds.num_rows();
    let mut split_maps = Vec::with_capacity(d);
    let mut split_dicts = Vec::with_capacity(d);
    for f in 0..d {
        let alpha = match &alphabets.splits[f] {
            SplitAlphabet::Numeric(vals) => DictCost::numerical_splits(n_obs, vals.len()),
            SplitAlphabet::Categorical(masks) => DictCost::categorical_splits(masks.len()),
        };
        let (map, counts) =
            cluster_family(&models.splits[f], alpha, opts.k_max, opts.seed ^ (f as u64), engine)?;
        if !counts.is_empty() {
            cluster_ks.push((format!("splits[{f}]"), counts.len()));
        }
        split_maps.push(map);
        split_dicts.push(
            counts
                .iter()
                .map(|c| huffman_from_counts(c))
                .collect::<Result<Vec<_>>>()?,
        );
    }

    // fits
    let fit_alpha_size = alphabets.fit_alphabet_size(forest);
    let mut fit_codec = if forest.classification && forest.classes == 2 {
        FitCodec::Arith
    } else {
        FitCodec::Huffman
    };
    let (mut fit_map, fit_counts) = cluster_family(
        &models.fits,
        DictCost::fits(opts.fit_alpha_bits, fit_alpha_size),
        opts.k_max,
        opts.seed ^ 0xf17,
        engine,
    )?;
    let (mut fit_dicts, fit_models_arith): (Vec<HuffmanCode>, Vec<FreqModel>) = match fit_codec {
        FitCodec::Huffman => (
            fit_counts
                .iter()
                .map(|c| huffman_from_counts(c))
                .collect::<Result<_>>()?,
            Vec::new(),
        ),
        _ => (
            Vec::new(),
            fit_counts
                .iter()
                .map(|c| FreqModel::from_probs(&crate::coding::entropy::normalize(c)))
                .collect::<Result<_>>()?,
        ),
    };
    // Regression escape hatch: when fits are mostly unique, the value
    // table + Huffman indices cost more than writing each fit inline
    // through the sign/exponent codec (~54 bits for typical data; the
    // paper's fits barely compress either: 122.1 → 118 MB on Liberty⁺).
    // Compare exactly and pick the cheaper representation. Quantized
    // forests (lossy §7) have C ≪ N and stay indexed.
    let mut fit_raw_codec: Option<F64Codec> = None;
    if !forest.classification {
        let total_fits: u64 = models.fits.values().flat_map(|v| v.iter()).sum();
        let indexed_bits: f64 = fit_counts
            .iter()
            .zip(&fit_dicts)
            .map(|(counts, dict)| {
                let payload: u64 = counts
                    .iter()
                    .enumerate()
                    .map(|(s, &c)| c * dict.length(s as u32) as u64)
                    .sum();
                (payload + dict.dict_bits()) as f64
            })
            .sum::<f64>()
            // table cost under the f64 block codec (~54 bits/value)
            + alphabets.fits.len() as f64 * 54.0;
        let codec = F64Codec::from_values(alphabets.fits.iter())?;
        // expected raw bits: each node fit once, weighted by counts —
        // approximate with the table values (every fit is in the table)
        let raw_bits =
            codec.expected_bits(&alphabets.fits) * total_fits as f64 + codec.dict_bits() as f64;
        if raw_bits <= indexed_bits {
            fit_codec = FitCodec::Raw64;
            fit_map = BTreeMap::new();
            fit_dicts = Vec::new();
            fit_raw_codec = Some(codec);
        }
    }
    cluster_ks.push((
        "fits".to_string(),
        if fit_codec == FitCodec::Raw64 { 1 } else { fit_counts.len().max(1) },
    ));

    // paper mode: numeric thresholds → observation ranks (a property of the
    // shared alphabets, so it lives in the plan, not the per-member encode)
    let indexed_splits: Vec<Option<Vec<u64>>> = if opts.dataset_indexed_splits {
        alphabets
            .splits
            .iter()
            .enumerate()
            .map(|(f, a)| match a {
                SplitAlphabet::Numeric(vals) if !vals.is_empty() => {
                    let uniq = crate::model::extract::ValueAlphabets::column_unique(ds, f)
                        .expect("numeric column");
                    let ranks = vals
                        .iter()
                        .map(|v| {
                            uniq.binary_search_by(|x| x.partial_cmp(v).unwrap())
                                .expect("threshold is an observed value")
                                as u64
                        })
                        .collect();
                    Some(ranks)
                }
                _ => None,
            })
            .collect()
    } else {
        vec![None; alphabets.splits.len()]
    };
    let features = ds
        .features
        .iter()
        .map(|f| FeatureMeta {
            name: f.name.clone(),
            levels: match &f.column {
                Column::Numeric(_) => None,
                Column::Categorical { levels, .. } => Some(*levels),
            },
        })
        .collect();

    Ok(CodecPlan {
        classification: forest.classification,
        classes: forest.classes,
        features,
        fit_codec,
        conditioning: opts.conditioning,
        alphabets,
        indexed_splits,
        vn_map,
        split_maps,
        fit_map,
        vn_dicts,
        split_dicts,
        fit_dicts,
        fit_models: fit_models_arith,
        fit_raw_codec,
        chains: opts.chains.clone(),
        cluster_ks,
    })
}

/// Stages 1 + 4 of Algorithm 1 against a frozen [`CodecPlan`]: Zaks-code the
/// member's structure, Huffman/arith-encode its nodes with the plan's
/// codebooks, and serialize a fully standalone `RFCZ` container carrying the
/// plan's complete side information. Members of a cohort encoded against one
/// plan therefore serialize **byte-identical** TABLES/CLUSMAP/DICTS sections
/// — the invariant the pack format's shared-codebook dedup rides on.
pub(crate) fn encode_with_plan(
    forest: &Forest,
    plan: &CodecPlan,
    workers: usize,
) -> Result<CompressedForest> {
    if forest.trees.is_empty() {
        bail!("cannot compress an empty forest");
    }
    if forest.classification != plan.classification || forest.classes != plan.classes {
        bail!(
            "forest target (classification={}, classes={}) disagrees with the codec plan \
             (classification={}, classes={})",
            forest.classification,
            forest.classes,
            plan.classification,
            plan.classes
        );
    }

    // ---- stage 1: structure ----
    let (zaks_bits, _lens) = zaks::concat_forest_zaks(&forest.trees);
    let packed = container::pack_bits(&zaks_bits);
    let struct_bytes = if !plan.chains.structure.is_empty() {
        // mode 2 = stage-chain coded; the header records the chain
        let mut v = vec![2u8];
        v.extend(
            stage::encode_chain(&plan.chains.structure, stage::BufferList::from_single(packed))
                .context("structure chain")?,
        );
        v
    } else {
        // LZ helps when trees resemble each other (shallow forests, small
        // data); deep unpruned forests have near-i.i.d. structure bits and
        // LZ's flags only add overhead — keep whichever is smaller (the
        // container records the choice).
        let lz = crate::coding::lz::compress_to_bytes(&packed);
        if lz.len() < packed.len() {
            let mut v = vec![0u8]; // mode 0 = LZSS
            v.extend(lz);
            v
        } else {
            let mut v = vec![1u8]; // mode 1 = raw packed
            v.extend(packed);
            v
        }
    };

    // ---- stage 4: per-tree encoding ----
    let encode_one = |tree: &Tree| -> Result<(Vec<u8>, Vec<u8>, Vec<u8>)> {
        let mut vars_w = BitWriter::new();
        let mut splits_w = BitWriter::new();
        let mut fits_w = BitWriter::new();
        let mut err: Option<anyhow::Error> = None;
        match plan.fit_codec {
            FitCodec::Raw64 => {
                let codec = plan.fit_raw_codec.as_ref().expect("raw codec built");
                tree.visit_preorder(|_, node, depth, father| {
                    if err.is_some() {
                        return;
                    }
                    let key = plan.conditioning.project(ContextKey::new(depth, father));
                    if let Err(e) = encode_node(
                        node,
                        key,
                        &plan.alphabets,
                        &plan.vn_map,
                        &plan.vn_dicts,
                        &plan.split_maps,
                        &plan.split_dicts,
                        &mut vars_w,
                        &mut splits_w,
                    ) {
                        err = Some(e);
                        return;
                    }
                    match node.fit {
                        Fit::Regression(v) => {
                            if let Err(e) = codec.encode(v, &mut fits_w) {
                                err = Some(e);
                            }
                        }
                        Fit::Class(_) => {
                            err = Some(anyhow::anyhow!("class fit in raw regression mode"))
                        }
                    }
                });
            }
            FitCodec::Huffman => {
                tree.visit_preorder(|_, node, depth, father| {
                    if err.is_some() {
                        return;
                    }
                    let key = plan.conditioning.project(ContextKey::new(depth, father));
                    if let Err(e) = encode_node(
                        node,
                        key,
                        &plan.alphabets,
                        &plan.vn_map,
                        &plan.vn_dicts,
                        &plan.split_maps,
                        &plan.split_dicts,
                        &mut vars_w,
                        &mut splits_w,
                    )
                    .and_then(|_| {
                        let sym = plan.alphabets.fit_symbol(&node.fit);
                        let cl = *plan.fit_map.get(&key).context("fit cluster missing")?;
                        plan.fit_dicts[cl as usize].encode(sym, &mut fits_w)
                    }) {
                        err = Some(e);
                    }
                });
            }
            FitCodec::Arith => {
                // collect (cluster, symbol) first: the arith encoder
                // borrows the writer for the whole tree
                let mut fit_syms: Vec<(u32, u32)> = Vec::with_capacity(tree.nodes.len());
                tree.visit_preorder(|_, node, depth, father| {
                    if err.is_some() {
                        return;
                    }
                    let key = plan.conditioning.project(ContextKey::new(depth, father));
                    if let Err(e) = encode_node(
                        node,
                        key,
                        &plan.alphabets,
                        &plan.vn_map,
                        &plan.vn_dicts,
                        &plan.split_maps,
                        &plan.split_dicts,
                        &mut vars_w,
                        &mut splits_w,
                    ) {
                        err = Some(e);
                        return;
                    }
                    let sym = plan.alphabets.fit_symbol(&node.fit);
                    match plan.fit_map.get(&key) {
                        Some(&cl) => fit_syms.push((cl, sym)),
                        None => err = Some(anyhow::anyhow!("fit cluster missing")),
                    }
                });
                if err.is_none() {
                    let mut enc = ArithEncoder::new(&mut fits_w);
                    for (cl, sym) in fit_syms {
                        enc.encode(&plan.fit_models[cl as usize], sym)?;
                    }
                    enc.finish();
                }
            }
        }
        if let Some(e) = err {
            return Err(e);
        }
        Ok((vars_w.into_bytes(), splits_w.into_bytes(), fits_w.into_bytes()))
    };

    let encoded =
        crate::util::threads::parallel_map(&forest.trees, workers, |_, t| encode_one(t));
    let mut vars_trees = Vec::with_capacity(forest.trees.len());
    let mut splits_trees = Vec::with_capacity(forest.trees.len());
    let mut fits_trees = Vec::with_capacity(forest.trees.len());
    for r in encoded {
        let (v, s, f) = r?;
        vars_trees.push(v);
        splits_trees.push(s);
        fits_trees.push(f);
    }

    // ---- assemble ----
    // the builder borrows the frozen plan: no per-member clone of the
    // alphabets, cluster maps, or codebooks (a cohort serializes every
    // member straight from the one shared plan)
    let builder = ContainerBuilder {
        plan,
        n_trees: forest.trees.len(),
        struct_bytes,
        vars_trees,
        splits_trees,
        fits_trees,
    };
    let (bytes, sizes) = builder.serialize()?;
    Ok(CompressedForest { bytes: bytes.into(), sizes, cluster_ks: plan.cluster_ks.clone() })
}

/// Cluster one model family: sweep K, densify cluster ids to the non-empty
/// ones, return (key → dense cluster id, per-cluster aggregated counts).
fn cluster_family(
    table: &CountTable,
    alpha: DictCost,
    k_max: usize,
    seed: u64,
    engine: &mut dyn LloydEngine,
) -> Result<(BTreeMap<ContextKey, u32>, Vec<Vec<u64>>)> {
    let nonempty = table.values().any(|v| v.iter().any(|&c| c > 0));
    if !nonempty {
        return Ok((BTreeMap::new(), Vec::new()));
    }
    let sw = sweep_k(table, alpha, k_max, seed, engine)?;
    let counts = cluster_counts(table, &sw.keys, &sw.best.assignments, sw.best.k);
    // densify: drop empty clusters
    let mut remap = vec![u32::MAX; sw.best.k];
    let mut dense_counts = Vec::new();
    for (k, c) in counts.into_iter().enumerate() {
        if c.iter().any(|&x| x > 0) {
            remap[k] = dense_counts.len() as u32;
            dense_counts.push(c);
        }
    }
    let mut map = assignment_map(&sw.keys, &sw.best.assignments);
    for v in map.values_mut() {
        let dense = remap[*v as usize];
        debug_assert_ne!(dense, u32::MAX, "assigned cluster cannot be empty");
        *v = dense;
    }
    Ok((map, dense_counts))
}

fn huffman_from_counts(counts: &[u64]) -> Result<HuffmanCode> {
    let weights: Vec<f64> = counts.iter().map(|&c| c as f64).collect();
    HuffmanCode::from_weights(&weights)
}

#[allow(clippy::too_many_arguments)]
fn encode_node(
    node: &Node,
    key: ContextKey,
    alphabets: &ValueAlphabets,
    vn_map: &BTreeMap<ContextKey, u32>,
    vn_dicts: &[HuffmanCode],
    split_maps: &[BTreeMap<ContextKey, u32>],
    split_dicts: &[Vec<HuffmanCode>],
    vars_w: &mut BitWriter,
    splits_w: &mut BitWriter,
) -> Result<()> {
    if let Some((split, _, _)) = &node.split {
        let f = split.feature as usize;
        let vcl = *vn_map.get(&key).context("var-name cluster missing")?;
        vn_dicts[vcl as usize].encode(split.feature, vars_w)?;
        let sym = alphabets.splits[f]
            .symbol_of(&split.value)
            .context("split value not in alphabet")?;
        let scl = *split_maps[f].get(&key).context("split cluster missing")?;
        split_dicts[f][scl as usize].encode(sym, splits_w)?;
    }
    Ok(())
}

// ------------------------------------------------------------- decompression

/// Decode every tree of a parsed container.
pub fn decompress_container(pc: &ParsedContainer) -> Result<Forest> {
    if pc.needs_dataset() {
        bail!("dataset-indexed container: attach_dataset() before decoding");
    }
    let seqs = zaks::split_concatenated(&pc.zaks_bits, pc.n_trees)?;
    let vn_decoders: Vec<HuffmanDecoder> = pc.vn_dicts.iter().map(|d| d.decoder()).collect();
    let split_decoders: Vec<Vec<HuffmanDecoder>> = pc
        .split_dicts
        .iter()
        .map(|per| per.iter().map(|d| d.decoder()).collect())
        .collect();
    let fit_decoders: Vec<HuffmanDecoder> = pc.fit_dicts.iter().map(|d| d.decoder()).collect();

    let mut trees = Vec::with_capacity(pc.n_trees);
    for t in 0..pc.n_trees {
        let shape = zaks::shape_from_zaks(&seqs[t])
            .with_context(|| format!("tree {t} structure"))?;
        let tree = decode_tree(pc, t, &shape, &vn_decoders, &split_decoders, &fit_decoders)
            .with_context(|| format!("tree {t}"))?;
        trees.push(tree);
    }
    Ok(Forest {
        trees,
        classification: pc.classification,
        classes: pc.classes,
    })
}

/// Decode one tree's nodes from its per-tree payload slices.
pub fn decode_tree(
    pc: &ParsedContainer,
    t: usize,
    shape: &zaks::TreeShape,
    vn_decoders: &[HuffmanDecoder],
    split_decoders: &[Vec<HuffmanDecoder>],
    fit_decoders: &[HuffmanDecoder],
) -> Result<Tree> {
    let n = shape.node_count();
    let depths = shape.depths();
    let mut vars_r = BitReader::new(pc.tree_vars(t));
    let mut splits_r = BitReader::new(pc.tree_splits(t));
    let mut fits_r = BitReader::new(pc.tree_fits(t));
    let mut arith = match pc.fit_codec {
        FitCodec::Arith => Some(ArithDecoder::new(fits_r.clone())),
        FitCodec::Huffman | FitCodec::Raw64 => None,
    };

    let mut father_feat: Vec<Option<u32>> = vec![None; n];
    let mut nodes = Vec::with_capacity(n);
    for i in 0..n {
        let key = pc
            .conditioning
            .project(ContextKey::new(depths[i], father_feat[i]));
        // fit first (all nodes carry one; encoder wrote it per node in
        // preorder — order matches)
        let fit = match (&mut arith, pc.fit_codec) {
            (Some(dec), FitCodec::Arith) => {
                let cl = *pc.fit_map.get(&key).context("fit cluster missing")?;
                let model = pc
                    .fit_models
                    .get(cl as usize)
                    .context("fit cluster id out of range")?;
                let sym = dec.decode(model)?;
                Fit::Class(sym)
            }
            (None, FitCodec::Huffman) => {
                let cl = *pc.fit_map.get(&key).context("fit cluster missing")?;
                let sym = fit_decoders
                    .get(cl as usize)
                    .context("fit cluster id out of range")?
                    .decode(&mut fits_r)?;
                if pc.classification {
                    Fit::Class(sym)
                } else {
                    let v = *pc
                        .alphabets
                        .fits
                        .get(sym as usize)
                        .context("fit symbol out of table")?;
                    Fit::Regression(v)
                }
            }
            (None, FitCodec::Raw64) => {
                let codec = pc.fit_raw_codec.as_ref().context("raw codec missing")?;
                Fit::Regression(codec.decode(&mut fits_r)?)
            }
            _ => unreachable!(),
        };
        let split = match shape.children[i] {
            None => None,
            Some((l, r)) => {
                let vcl = *pc.vn_map.get(&key).context("vn cluster missing")?;
                let feature = vn_decoders
                    .get(vcl as usize)
                    .context("vn cluster id out of range")?
                    .decode(&mut vars_r)?;
                if feature as usize >= pc.features.len() {
                    bail!("decoded feature {feature} out of range");
                }
                let scl = *pc.split_maps[feature as usize]
                    .get(&key)
                    .context("split cluster missing")?;
                let sym = split_decoders[feature as usize]
                    .get(scl as usize)
                    .context("split cluster id out of range")?
                    .decode(&mut splits_r)?;
                let value = split_value_of(&pc.alphabets.splits[feature as usize], sym)?;
                father_feat[l as usize] = Some(feature);
                father_feat[r as usize] = Some(feature);
                Some((Split { feature, value }, l, r))
            }
        };
        nodes.push(Node { split, fit });
    }
    Ok(Tree { nodes })
}

fn split_value_of(alpha: &SplitAlphabet, sym: u32) -> Result<crate::forest::SplitValue> {
    if (sym as usize) < alpha.len() {
        Ok(alpha.value_of(sym))
    } else {
        bail!("split symbol {sym} out of alphabet (size {})", alpha.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::forest::ForestParams;

    fn roundtrip(ds: &Dataset, forest: &Forest, opts: &CompressOptions) -> CompressedForest {
        let cf = CompressedForest::compress(forest, ds, opts).unwrap();
        let restored = cf.decompress().unwrap();
        assert!(forest.identical(&restored), "lossless round-trip failed");
        cf
    }

    #[test]
    fn lossless_roundtrip_classification() {
        let ds = synthetic::iris(1);
        let f = Forest::train(&ds, &ForestParams::classification(8), 2);
        let cf = roundtrip(&ds, &f, &CompressOptions::default());
        assert!(cf.total_bytes() > 0);
        assert_eq!(cf.sizes.total(), cf.total_bytes());
    }

    #[test]
    fn lossless_roundtrip_regression() {
        let ds = synthetic::airfoil_regression(2);
        let f = Forest::train(&ds, &ForestParams::regression(4), 3);
        roundtrip(&ds, &f, &CompressOptions::default());
    }

    #[test]
    fn lossless_roundtrip_two_class_uses_arith() {
        let ds = synthetic::airfoil_classification(3);
        let f = Forest::train(&ds, &ForestParams::classification(5), 4);
        let cf = roundtrip(&ds, &f, &CompressOptions::default());
        let pc = cf.parse().unwrap();
        assert_eq!(pc.fit_codec, FitCodec::Arith);
    }

    #[test]
    fn lossless_roundtrip_multiclass_uses_huffman() {
        let ds = synthetic::iris(4);
        let f = Forest::train(&ds, &ForestParams::classification(4), 5);
        let cf = roundtrip(&ds, &f, &CompressOptions::default());
        assert_eq!(cf.parse().unwrap().fit_codec, FitCodec::Huffman);
    }

    #[test]
    fn lossless_with_categorical_features() {
        let ds = synthetic::wages(5);
        let f = Forest::train(&ds, &ForestParams::classification(6), 6);
        roundtrip(&ds, &f, &CompressOptions::default());
    }

    #[test]
    fn lossless_all_conditionings() {
        let ds = synthetic::iris(6);
        let f = Forest::train(&ds, &ForestParams::classification(4), 7);
        for c in [
            ModelConditioning::DepthFather,
            ModelConditioning::DepthOnly,
            ModelConditioning::None,
        ] {
            let opts = CompressOptions { conditioning: c, ..Default::default() };
            roundtrip(&ds, &f, &opts);
        }
    }

    #[test]
    fn compression_beats_naive_size() {
        let ds = synthetic::shuttle(7);
        let f = Forest::train(&ds, &ForestParams::classification(10), 8);
        let cf = roundtrip(&ds, &f, &CompressOptions::default());
        // naive: ~ (feature u32 + value f64 + fit u32) per node
        let naive = f.total_nodes() as u64 * 16;
        assert!(
            cf.total_bytes() < naive,
            "compressed {} should beat naive {naive}",
            cf.total_bytes()
        );
    }

    #[test]
    fn single_tree_forest() {
        let ds = synthetic::iris(8);
        let f = Forest::train(&ds, &ForestParams::classification(1), 9);
        roundtrip(&ds, &f, &CompressOptions::default());
    }

    #[test]
    fn tiny_trees_forest() {
        // depth-1 stumps: exercises root-only + leaf-heavy paths
        let ds = synthetic::iris(9);
        let params = ForestParams {
            tree: crate::forest::TreeParams { mtry: Some(2), min_leaf: 1, max_depth: 1 },
            ..ForestParams::classification(6)
        };
        let f = Forest::train(&ds, &params, 10);
        roundtrip(&ds, &f, &CompressOptions::default());
    }

    #[test]
    fn deterministic_output() {
        let ds = synthetic::iris(10);
        let f = Forest::train(&ds, &ForestParams::classification(5), 11);
        let a = CompressedForest::compress(&f, &ds, &CompressOptions::default()).unwrap();
        let b = CompressedForest::compress(&f, &ds, &CompressOptions::default()).unwrap();
        assert_eq!(a.bytes, b.bytes);
    }

    #[test]
    fn workers_do_not_change_output() {
        let ds = synthetic::iris(11);
        let f = Forest::train(&ds, &ForestParams::classification(5), 12);
        let a = CompressedForest::compress(&f, &ds, &CompressOptions::default()).unwrap();
        let opts = CompressOptions { workers: 4, ..Default::default() };
        let b = CompressedForest::compress(&f, &ds, &opts).unwrap();
        assert_eq!(a.bytes, b.bytes);
    }

    #[test]
    fn from_bytes_revalidates() {
        let ds = synthetic::iris(12);
        let f = Forest::train(&ds, &ForestParams::classification(3), 13);
        let cf = CompressedForest::compress(&f, &ds, &CompressOptions::default()).unwrap();
        let reloaded = CompressedForest::from_bytes(cf.bytes.clone()).unwrap();
        assert!(reloaded.decompress().unwrap().identical(&f));
        // corrupted magic must fail
        let mut bad = cf.bytes.to_vec();
        bad[0] = b'X';
        assert!(CompressedForest::from_bytes(bad).is_err());
    }

    #[test]
    fn truncation_errors_cleanly() {
        let ds = synthetic::iris(13);
        let f = Forest::train(&ds, &ForestParams::classification(3), 14);
        let cf = CompressedForest::compress(&f, &ds, &CompressOptions::default()).unwrap();
        for cut in [cf.bytes.len() / 4, cf.bytes.len() / 2, cf.bytes.len() - 3] {
            let res = CompressedForest::from_bytes(cf.bytes[..cut].to_vec())
                .and_then(|c| c.decompress());
            assert!(res.is_err(), "truncation at {cut} must error, not panic");
        }
    }

    #[test]
    fn paper_mode_roundtrip_needs_dataset() {
        let ds = synthetic::wages(16);
        let f = Forest::train(&ds, &ForestParams::classification(6), 17);
        let opts = CompressOptions { dataset_indexed_splits: true, ..Default::default() };
        let cf = CompressedForest::compress(&f, &ds, &opts).unwrap();
        // plain decompress must refuse
        assert!(cf.decompress().is_err());
        // with the training data: bit-exact
        let restored = cf.decompress_with_dataset(&ds).unwrap();
        assert!(restored.identical(&f));
        // wrong dataset: clean error or detectable mismatch, no panic
        let other = synthetic::iris(16);
        assert!(cf.decompress_with_dataset(&other).is_err());
    }

    #[test]
    fn paper_mode_is_smaller_than_self_contained() {
        let ds = synthetic::airfoil_classification(18);
        let f = Forest::train(&ds, &ForestParams::classification(20), 19);
        let a = CompressedForest::compress(&f, &ds, &CompressOptions::default()).unwrap();
        let opts = CompressOptions { dataset_indexed_splits: true, ..Default::default() };
        let b = CompressedForest::compress(&f, &ds, &opts).unwrap();
        assert!(
            b.total_bytes() < a.total_bytes(),
            "indexed {} must beat self-contained {}",
            b.total_bytes(),
            a.total_bytes()
        );
        assert!(b.decompress_with_dataset(&ds).unwrap().identical(&f));
    }

    #[test]
    fn paper_mode_predictions_from_compressed() {
        let ds = synthetic::airfoil_classification(20);
        let f = Forest::train(&ds, &ForestParams::classification(6), 21);
        let opts = CompressOptions { dataset_indexed_splits: true, ..Default::default() };
        let cf = CompressedForest::compress(&f, &ds, &opts).unwrap();
        let mut pc = cf.parse().unwrap();
        assert!(pc.needs_dataset());
        pc.attach_dataset(&ds).unwrap();
        let p = crate::compress::CompressedPredictor::new(pc).unwrap();
        for row in (0..ds.num_rows()).step_by(131) {
            let expect = f.predict_class(&ds, row);
            assert_eq!(
                p.predict_row(&ds, row).unwrap(),
                crate::compress::predict::PredictOne::Class(expect)
            );
        }
    }

    #[test]
    fn chained_container_roundtrips_and_bumps_version() {
        use crate::coding::stage::parse_chain;
        let ds = synthetic::iris(30);
        let f = Forest::train(&ds, &ForestParams::classification(5), 31);
        let chains = SectionChains {
            structure: parse_chain("lzss").unwrap(),
            split_tables: parse_chain("delta+lzss").unwrap(),
            fit_table: parse_chain("xor+huff").unwrap(),
        };
        let opts = CompressOptions { chains: chains.clone(), ..Default::default() };
        let cf = CompressedForest::compress(&f, &ds, &opts).unwrap();
        assert_eq!(cf.bytes[4], container::VERSION_CHAINED, "chained ⇒ version 2");
        let pc = cf.parse().unwrap();
        assert_eq!(pc.chains, chains, "header records the chains");
        assert!(cf.decompress().unwrap().identical(&f), "lossless chains stay bit-exact");
    }

    #[test]
    fn default_chains_reproduce_the_legacy_encoder_bytes() {
        // the differential oracle's cheap half: explicitly-empty chains and
        // the default options are the same plan, so the bytes agree and the
        // container stays version 1 (the pre-refactor wire format)
        let ds = synthetic::wages(34);
        let f = Forest::train(&ds, &ForestParams::classification(5), 35);
        let a = CompressedForest::compress(&f, &ds, &CompressOptions::default()).unwrap();
        let opts =
            CompressOptions { chains: SectionChains::default(), ..Default::default() };
        let b = CompressedForest::compress(&f, &ds, &opts).unwrap();
        assert_eq!(a.bytes, b.bytes);
        assert_eq!(a.bytes[4], container::VERSION);
    }

    #[test]
    fn lossy_fit_chain_stays_within_theory_bound() {
        use crate::coding::stage::parse_chain;
        let ds = synthetic::airfoil_regression(32);
        let f = Forest::train(&ds, &ForestParams::regression(5), 33);
        let chains = SectionChains {
            fit_table: parse_chain("bf16+lzss").unwrap(),
            ..Default::default()
        };
        let opts = CompressOptions { chains: chains.clone(), ..Default::default() };
        let cf = CompressedForest::compress(&f, &ds, &opts).unwrap();
        assert_eq!(cf.bytes[4], container::VERSION_CHAINED);
        let g = cf.decompress().unwrap();
        let fits_of = |fo: &Forest| -> Vec<f64> {
            fo.trees
                .iter()
                .flat_map(|t| t.nodes.iter())
                .map(|n| match n.fit {
                    Fit::Regression(v) => v,
                    Fit::Class(_) => unreachable!("regression forest"),
                })
                .collect()
        };
        let (orig, dec) = (fits_of(&f), fits_of(&g));
        assert_eq!(orig.len(), dec.len());
        let vmax = orig.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        let bound = crate::lossy::theory::chain_mse_bound(&chains.fit_table, vmax).unwrap();
        for (a, b) in orig.iter().zip(&dec) {
            let se = (a - b) * (a - b);
            assert!(se <= bound, "fit {a} decoded as {b}: {se} > bound {bound}");
        }
        // structure and splits are untouched by a fit-table chain
        assert_eq!(f.total_nodes(), g.total_nodes());
    }

    #[test]
    fn lossy_chain_on_classification_is_rejected() {
        use crate::coding::stage::parse_chain;
        let ds = synthetic::iris(36);
        let f = Forest::train(&ds, &ForestParams::classification(3), 37);
        let chains = SectionChains {
            fit_table: parse_chain("f32").unwrap(),
            ..Default::default()
        };
        let opts = CompressOptions { chains, ..Default::default() };
        let err = CompressedForest::compress(&f, &ds, &opts).unwrap_err().to_string();
        assert!(err.contains("chain"), "typed chain-validation error, got: {err}");
    }

    #[test]
    fn sizes_sum_to_total() {
        let ds = synthetic::wages(14);
        let f = Forest::train(&ds, &ForestParams::classification(4), 15);
        let cf = CompressedForest::compress(&f, &ds, &CompressOptions::default()).unwrap();
        assert_eq!(cf.sizes.total(), cf.bytes.len() as u64);
        let pc = cf.parse().unwrap();
        assert_eq!(pc.sizes, cf.sizes, "parser must recover the same breakdown");
        let cols = cf.sizes.paper_columns();
        assert_eq!(cols.total(), cf.total_bytes());
    }

    #[test]
    fn predictions_preserved_through_roundtrip() {
        let ds = synthetic::airfoil_classification(15);
        let f = Forest::train(&ds, &ForestParams::classification(7), 16);
        let cf = CompressedForest::compress(&f, &ds, &CompressOptions::default()).unwrap();
        let g = cf.decompress().unwrap();
        for row in (0..ds.num_rows()).step_by(97) {
            assert_eq!(f.predict_class(&ds, row), g.predict_class(&ds, row));
        }
    }
}
