//! Prediction straight from the compressed bytes (paper §5).
//!
//! The Huffman codes' prefix property means a tree's node symbols can be
//! decoded one at a time from its (byte-aligned, offset-indexed) stream
//! without decoding the rest of the container. Because symbols are laid out
//! in preorder and the Zaks shape gives every node's children, a
//! root-to-leaf walk decodes exactly the **preorder prefix** up to the
//! reached leaf: following a left edge costs one more node; following a
//! right edge decode-skips the left subtree (decoding its symbols to stay
//! in stream sync, but building nothing).
//!
//! RAM per query is `O(tree nodes)` for the shape bits + father-feature
//! scratch — the paper's "2n+1 bits in RAM" plus bookkeeping; the forest
//! itself is never materialized.
//!
//! Two query modes:
//! * [`CompressedPredictor::predict_row`] — single observation, prefix
//!   decode per tree (the subscriber-device path);
//! * [`CompressedPredictor::predict_all`] — batch: trees are decoded into
//!   struct-of-arrays [`FlatTree`] plans (memoized across batches by an
//!   optional [`PlanCache`]) and rows are routed through them in blocks of
//!   [`super::flat::BLOCK`]; wide batches on few-tree forests parallelize
//!   across row ranges, tree-rich forests across trees (see
//!   [`CompressedPredictor::predict_all_workers`] for the axis rule).

use super::container::{FitCodec, ParsedContainer};
use super::flat::{self, ColRef, FlatTree, PlanCache};
use super::pipeline::decompress_container;
use crate::coding::arith::ArithDecoder;
use crate::coding::bitio::BitReader;
use crate::coding::huffman::HuffmanDecoder;
use crate::data::{Column, Dataset};
use crate::forest::forest::Predictions;
use crate::model::keys::ContextKey;
use crate::zaks::{self, TreeShape};
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// A prediction engine over a parsed container. Owns the container through
/// an `Arc`, so it can live in long-running services (the model store); the
/// container itself only *views* the shared byte buffer, so any number of
/// predictors over one model cost a single resident copy.
pub struct CompressedPredictor {
    pc: Arc<ParsedContainer>,
    /// per-tree Zaks shapes (split once on construction)
    shapes: Vec<TreeShape>,
    vn_decoders: Vec<HuffmanDecoder>,
    split_decoders: Vec<Vec<HuffmanDecoder>>,
    fit_decoders: Vec<HuffmanDecoder>,
    /// worker threads for the batch path (1 = sequential).
    workers: usize,
    /// shared flat-plan cache; `None` decodes plans per batch.
    plan_cache: Option<Arc<PlanCache>>,
}

impl CompressedPredictor {
    /// Build from a parsed container (cheap relative to decompression: one
    /// pass over the Zaks bits + decoder table construction).
    pub fn new(pc: impl Into<Arc<ParsedContainer>>) -> Result<Self> {
        let pc: Arc<ParsedContainer> = pc.into();
        if pc.needs_dataset() {
            bail!(
                "dataset-indexed container: call ParsedContainer::attach_dataset \
                 with the training data before building a predictor"
            );
        }
        let seqs = zaks::split_concatenated(&pc.zaks_bits, pc.n_trees)?;
        let shapes = seqs
            .iter()
            .enumerate()
            .map(|(t, s)| zaks::shape_from_zaks(s).with_context(|| format!("tree {t}")))
            .collect::<Result<Vec<_>>>()?;
        let vn_decoders = pc.vn_dicts.iter().map(|d| d.decoder()).collect();
        let split_decoders = pc
            .split_dicts
            .iter()
            .map(|per| per.iter().map(|d| d.decoder()).collect())
            .collect();
        let fit_decoders = pc.fit_dicts.iter().map(|d| d.decoder()).collect();
        Ok(CompressedPredictor {
            pc,
            shapes,
            vn_decoders,
            split_decoders,
            fit_decoders,
            workers: 1,
            plan_cache: None,
        })
    }

    /// Set the worker-thread count used by [`Self::predict_all`] (builder
    /// style); 1 keeps the sequential path.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Share a [`PlanCache`] (builder style): decoded [`FlatTree`] plans are
    /// memoized per `(model, tree)` across batches, so a warm model skips
    /// the Huffman decode entirely. Without a cache every batch decodes its
    /// trees transiently (memory `O(decoded trees in flight)`).
    pub fn with_plan_cache(mut self, cache: Arc<PlanCache>) -> Self {
        self.plan_cache = Some(cache);
        self
    }

    /// The plan-cache model key of this predictor (the parse's unique id).
    pub fn model_id(&self) -> u64 {
        self.pc.plan_id()
    }

    /// The configured batch worker count.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The underlying container.
    pub fn container(&self) -> &ParsedContainer {
        &self.pc
    }

    /// Validate that a dataset's schema matches the container (feature kinds
    /// and counts; prediction routes on these).
    pub fn check_schema(&self, ds: &Dataset) -> Result<()> {
        if ds.num_features() != self.pc.features.len() {
            bail!(
                "dataset has {} features, container {}",
                ds.num_features(),
                self.pc.features.len()
            );
        }
        for (f, meta) in ds.features.iter().zip(&self.pc.features) {
            let ok = match (&f.column, meta.levels) {
                (Column::Numeric(_), None) => true,
                (Column::Categorical { levels, .. }, Some(l)) => *levels == l,
                _ => false,
            };
            if !ok {
                bail!("feature kind mismatch on {:?}", meta.name);
            }
        }
        Ok(())
    }

    /// Number of trees in the underlying forest.
    pub fn num_trees(&self) -> usize {
        self.pc.n_trees
    }

    /// Predict one row: regression mean / majority vote over all trees,
    /// each answered by a prefix decode of that tree's streams.
    pub fn predict_row(&self, ds: &Dataset, row: usize) -> Result<PredictOne> {
        let mut votes = vec![0u32; self.pc.classes.max(1) as usize];
        let mut sum = 0.0f64;
        for t in 0..self.pc.n_trees {
            match self.predict_tree_row(t, ds, row)? {
                TreeAnswer::Class(c) => votes[c as usize] += 1,
                TreeAnswer::Value(v) => sum += v,
            }
        }
        Ok(if self.pc.classification {
            PredictOne::Class(
                votes
                    .iter()
                    .enumerate()
                    .max_by_key(|&(i, &v)| (v, std::cmp::Reverse(i)))
                    .map(|(i, _)| i as u32)
                    .unwrap_or(0),
            )
        } else {
            PredictOne::Value(sum / self.pc.n_trees as f64)
        })
    }

    /// Single tree, single row: the §5 path decode.
    fn predict_tree_row(&self, t: usize, ds: &Dataset, row: usize) -> Result<TreeAnswer> {
        let shape = &self.shapes[t];
        let n = shape.node_count();
        let depths = shape.depths();
        let pc = &*self.pc;
        let mut vars_r = BitReader::new(pc.tree_vars(t));
        let mut splits_r = BitReader::new(pc.tree_splits(t));
        let mut fits_r = BitReader::new(pc.tree_fits(t));
        let mut arith = match pc.fit_codec {
            FitCodec::Arith => Some(ArithDecoder::new(fits_r.clone())),
            FitCodec::Huffman | FitCodec::Raw64 => None,
        };

        let mut father_feat: Vec<Option<u32>> = vec![None; n];
        // target node we are walking toward; decode sequentially until we
        // pass through it as a leaf
        let mut target = 0usize;
        let mut answer: Option<TreeAnswer> = None;
        for i in 0..n {
            let key = pc.conditioning.project(ContextKey::new(depths[i], father_feat[i]));
            // a fit is present for every node in stream order; decode (or
            // skip-decode) to stay in sync
            enum DecodedFit {
                Sym(u32),
                Raw(f64),
            }
            let fit = match (&mut arith, pc.fit_codec) {
                (Some(dec), FitCodec::Arith) => {
                    let cl = *pc.fit_map.get(&key).context("fit cluster")?;
                    let model = pc
                        .fit_models
                        .get(cl as usize)
                        .context("fit cluster id out of range")?;
                    DecodedFit::Sym(dec.decode(model)?)
                }
                (None, FitCodec::Huffman) => {
                    let cl = *pc.fit_map.get(&key).context("fit cluster")?;
                    DecodedFit::Sym(
                        self.fit_decoders
                            .get(cl as usize)
                            .context("fit cluster id out of range")?
                            .decode(&mut fits_r)?,
                    )
                }
                (None, FitCodec::Raw64) => DecodedFit::Raw(
                    pc.fit_raw_codec
                        .as_ref()
                        .context("raw codec missing")?
                        .decode(&mut fits_r)?,
                ),
                _ => unreachable!(),
            };
            match shape.children[i] {
                Some((l, r)) => {
                    let vcl = *pc.vn_map.get(&key).context("vn cluster")?;
                    let feature = self
                        .vn_decoders
                        .get(vcl as usize)
                        .context("vn cluster id out of range")?
                        .decode(&mut vars_r)?;
                    if feature as usize >= pc.features.len() {
                        bail!("decoded feature out of range");
                    }
                    let scl = *pc.split_maps[feature as usize]
                        .get(&key)
                        .context("split cluster")?;
                    let sym = self.split_decoders[feature as usize]
                        .get(scl as usize)
                        .context("split cluster id out of range")?
                        .decode(&mut splits_r)?;
                    father_feat[l as usize] = Some(feature);
                    father_feat[r as usize] = Some(feature);
                    if i == target {
                        // evaluate the split to choose the next target
                        let alpha = &pc.alphabets.splits[feature as usize];
                        if sym as usize >= alpha.len() {
                            bail!("split symbol out of alphabet");
                        }
                        let value = alpha.value_of(sym);
                        let split = crate::forest::Split { feature, value };
                        target = if crate::forest::tree::go_left(ds, row, &split) {
                            l as usize
                        } else {
                            r as usize
                        };
                    }
                }
                None => {
                    if i == target {
                        answer = Some(match fit {
                            DecodedFit::Sym(sym) if pc.classification => TreeAnswer::Class(sym),
                            DecodedFit::Sym(sym) => TreeAnswer::Value(
                                *pc.alphabets
                                    .fits
                                    .get(sym as usize)
                                    .context("fit symbol out of table")?,
                            ),
                            DecodedFit::Raw(v) => TreeAnswer::Value(v),
                        });
                        break; // nothing past the target leaf is needed
                    }
                }
            }
        }
        answer.context("walk never reached a leaf (corrupt shape)")
    }

    /// Batch prediction through the flat-tree execution engine: each tree is
    /// decoded once into a struct-of-arrays [`FlatTree`] (fetched from the
    /// shared [`PlanCache`] when one is configured — a warm model skips the
    /// Huffman decode entirely) and rows are routed through it in blocks of
    /// [`flat::BLOCK`]. Uses the configured worker count
    /// ([`Self::with_workers`]).
    pub fn predict_all(&self, ds: &Dataset) -> Result<Predictions> {
        self.predict_all_workers(ds, self.workers)
    }

    /// As [`Self::predict_all`] with an explicit worker count (the bench
    /// knob). A work-size heuristic picks the parallelism axis:
    ///
    /// * **trees** when the forest has enough of them to keep every worker
    ///   busy (classification only — vote counts commute exactly under any
    ///   sharding);
    /// * **rows** for wide batches on few-tree forests, and always for
    ///   regression: each worker owns a contiguous row range and folds fits
    ///   in tree order per row, so the result is **bit-identical** to the
    ///   sequential and per-row prefix-decode paths at any worker count
    ///   (tree sharding would reassociate the f64 sums).
    pub fn predict_all_workers(&self, ds: &Dataset, workers: usize) -> Result<Predictions> {
        self.check_schema(ds)?;
        let n_rows = ds.num_rows();
        let n_trees = self.pc.n_trees;
        if n_trees == 0 {
            bail!("empty forest");
        }
        let k = self.pc.classes.max(1) as usize;
        let workers = workers.max(1);
        let cols = flat::col_refs(ds);

        let (votes, sums) = if n_rows == 0 {
            (Vec::new(), Vec::new())
        } else if workers == 1 {
            // sequential: stream one plan at a time over all rows
            self.fold_trees(&cols, &(0..n_trees).collect::<Vec<_>>(), n_rows, k)?
        } else if self.row_axis(n_rows, n_trees, workers) {
            self.predict_row_parallel(&cols, n_rows, k, workers)?
        } else {
            // tree axis: shard trees across workers, reduce accumulators
            let tree_idx: Vec<usize> = (0..n_trees).collect();
            crate::util::threads::parallel_fold(
                &tree_idx,
                workers,
                |chunk| self.fold_trees(&cols, chunk, n_rows, k),
                |a, b| match (a, b) {
                    (Ok((mut va, mut sa)), Ok((vb, sb))) => {
                        for (x, y) in va.iter_mut().zip(&vb) {
                            *x += *y;
                        }
                        for (x, y) in sa.iter_mut().zip(&sb) {
                            *x += *y;
                        }
                        Ok((va, sa))
                    }
                    (Err(e), _) | (_, Err(e)) => Err(e),
                },
            )
            .context("empty forest")??
        };
        Ok(self.assemble(&votes, &sums, n_rows, k))
    }

    /// Fold per-row accumulators into [`Predictions`]: majority vote with
    /// ties to the smaller class, or the regression mean over trees. Shared
    /// by the flat engine and the re-decode baseline so the differential
    /// oracle can never diverge on aggregation alone.
    fn assemble(&self, votes: &[u32], sums: &[f64], n_rows: usize, k: usize) -> Predictions {
        if self.pc.classification {
            Predictions::Classes(
                (0..n_rows)
                    .map(|row| {
                        votes[row * k..(row + 1) * k]
                            .iter()
                            .enumerate()
                            .max_by_key(|&(i, &v)| (v, std::cmp::Reverse(i)))
                            .map(|(i, _)| i as u32)
                            .unwrap_or(0)
                    })
                    .collect(),
            )
        } else {
            Predictions::Values(sums.iter().map(|s| s / self.pc.n_trees as f64).collect())
        }
    }

    /// Work-size heuristic for the batch parallelism axis. Regression always
    /// takes the row axis (bit-identical aggregation, see
    /// [`Self::predict_all_workers`]); classification takes it only when the
    /// forest is too small to keep every worker busy on trees AND the batch
    /// is wide enough to give each worker full routing blocks.
    fn row_axis(&self, n_rows: usize, n_trees: usize, workers: usize) -> bool {
        if !self.pc.classification {
            return true;
        }
        n_trees < workers * 2 && n_rows >= workers * flat::BLOCK
    }

    /// Row-range parallelism: each worker owns a contiguous row range and
    /// mutates its disjoint slice of the shared accumulators, folding fits
    /// in tree order per row — bit-identical to the sequential path. Trees
    /// are decoded in bounded groups, so peak memory stays O(group of
    /// trees) rather than O(decoded forest) even with no plan cache (the
    /// PR-1 bound, kept).
    fn predict_row_parallel(
        &self,
        cols: &[ColRef],
        n_rows: usize,
        k: usize,
        workers: usize,
    ) -> Result<(Vec<u32>, Vec<f64>)> {
        let classification = self.pc.classification;
        let n_trees = self.pc.n_trees;
        let mut votes = vec![0u32; if classification { n_rows * k } else { 0 }];
        let mut sums = vec![0.0f64; if classification { 0 } else { n_rows }];
        let chunk = n_rows.div_ceil(workers).max(1);
        let ranges: Vec<std::ops::Range<usize>> = (0..n_rows)
            .step_by(chunk)
            .map(|s| s..(s + chunk).min(n_rows))
            .collect();
        // decoded-plans-in-flight bound; one group covers the common
        // row-axis case (few-tree forests) so the loop adds no overhead
        let group = (workers * 8).max(16);
        let mut start_tree = 0usize;
        while start_tree < n_trees {
            let end_tree = (start_tree + group).min(n_trees);
            let plans = self.flat_trees_range(start_tree..end_tree, workers)?;
            let plans = &plans;
            let results: Vec<Result<()>> = std::thread::scope(|scope| {
                let mut handles = Vec::new();
                if classification {
                    for (r, v) in ranges.iter().zip(votes.chunks_mut(chunk * k)) {
                        let range = r.clone();
                        handles.push(scope.spawn(move || -> Result<()> {
                            for plan in plans {
                                plan.accumulate(cols, range.clone(), k, v, &mut [])?;
                            }
                            Ok(())
                        }));
                    }
                } else {
                    for (r, s) in ranges.iter().zip(sums.chunks_mut(chunk)) {
                        let range = r.clone();
                        handles.push(scope.spawn(move || -> Result<()> {
                            for plan in plans {
                                plan.accumulate(cols, range.clone(), k, &mut [], s)?;
                            }
                            Ok(())
                        }));
                    }
                }
                handles.into_iter().map(|h| h.join().expect("row worker panicked")).collect()
            });
            for r in results {
                r?;
            }
            start_tree = end_tree;
        }
        Ok((votes, sums))
    }

    /// One worker's share of the tree axis: fetch (or decode) each assigned
    /// tree's plan and fold every row through it — no shared state, no
    /// locks; the caller reduces the per-worker accumulators in shard order.
    fn fold_trees(
        &self,
        cols: &[ColRef],
        trees: &[usize],
        n_rows: usize,
        k: usize,
    ) -> Result<(Vec<u32>, Vec<f64>)> {
        let classification = self.pc.classification;
        let mut votes = vec![0u32; if classification { n_rows * k } else { 0 }];
        let mut sums = vec![0.0f64; if classification { 0 } else { n_rows }];
        for &t in trees {
            self.flat_tree(t)?
                .accumulate(cols, 0..n_rows, k, &mut votes, &mut sums)
                .with_context(|| format!("tree {t}"))?;
        }
        Ok((votes, sums))
    }

    /// Fetch tree `t`'s flat plan: from the shared cache when configured
    /// (decode-once-per-model), otherwise decoded transiently.
    fn flat_tree(&self, t: usize) -> Result<Arc<FlatTree>> {
        let build = || {
            FlatTree::decode(
                &self.pc,
                t,
                &self.shapes[t],
                &self.vn_decoders,
                &self.split_decoders,
                &self.fit_decoders,
            )
        };
        match &self.plan_cache {
            Some(cache) => cache.get_or_build(self.pc.plan_id(), t as u32, build),
            None => Ok(Arc::new(build()?)),
        }
    }

    /// Materialize one group of tree plans (parallel decode on a cold cache).
    fn flat_trees_range(
        &self,
        trees: std::ops::Range<usize>,
        workers: usize,
    ) -> Result<Vec<Arc<FlatTree>>> {
        let idx: Vec<usize> = trees.collect();
        crate::util::threads::parallel_map(&idx, workers, |_, &t| self.flat_tree(t))
            .into_iter()
            .collect()
    }

    /// The PR-1 batch path, kept as the measured baseline and differential
    /// oracle: re-decode every tree into pointer-linked
    /// [`crate::forest::Tree`] nodes per batch and route rows one at a
    /// time. Sequential.
    pub fn predict_all_baseline(&self, ds: &Dataset) -> Result<Predictions> {
        self.check_schema(ds)?;
        let n_rows = ds.num_rows();
        let k = self.pc.classes.max(1) as usize;
        let classification = self.pc.classification;
        let mut votes = vec![0u32; if classification { n_rows * k } else { 0 }];
        let mut sums = vec![0.0f64; if classification { 0 } else { n_rows }];
        for t in 0..self.pc.n_trees {
            let tree = super::pipeline::decode_tree(
                &self.pc,
                t,
                &self.shapes[t],
                &self.vn_decoders,
                &self.split_decoders,
                &self.fit_decoders,
            )?;
            for row in 0..n_rows {
                match tree.predict_row(ds, row) {
                    crate::forest::Fit::Class(c) => {
                        if c as usize >= k {
                            bail!("decoded class {c} out of range (tree {t})");
                        }
                        votes[row * k + c as usize] += 1;
                    }
                    crate::forest::Fit::Regression(v) => sums[row] += v,
                }
            }
        }
        Ok(self.assemble(&votes, &sums, n_rows, k))
    }

    /// Full forest reconstruction (delegates to the pipeline decoder).
    pub fn decompress(&self) -> Result<crate::forest::Forest> {
        decompress_container(&self.pc)
    }
}

/// One aggregated prediction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PredictOne {
    /// A regression mean.
    Value(f64),
    /// A majority-vote class label.
    Class(u32),
}

#[derive(Debug, Clone, Copy)]
enum TreeAnswer {
    Value(f64),
    Class(u32),
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::pipeline::{CompressOptions, CompressedForest};
    use crate::data::synthetic;
    use crate::forest::{Forest, ForestParams};

    fn setup(
        ds: &Dataset,
        n_trees: usize,
        classification: bool,
    ) -> (Forest, CompressedForest) {
        let params = if classification {
            ForestParams::classification(n_trees)
        } else {
            ForestParams::regression(n_trees)
        };
        let f = Forest::train(ds, &params, 31);
        let cf = CompressedForest::compress(&f, ds, &CompressOptions::default()).unwrap();
        (f, cf)
    }

    #[test]
    fn row_predictions_match_original_classification() {
        let ds = synthetic::iris(21);
        let (f, cf) = setup(&ds, 7, true);
        let pc = cf.parse().unwrap();
        let p = CompressedPredictor::new(pc).unwrap();
        p.check_schema(&ds).unwrap();
        for row in (0..ds.num_rows()).step_by(13) {
            let expect = f.predict_class(&ds, row);
            assert_eq!(p.predict_row(&ds, row).unwrap(), PredictOne::Class(expect), "row {row}");
        }
    }

    #[test]
    fn row_predictions_match_original_regression() {
        let ds = synthetic::airfoil_regression(22);
        let (f, cf) = setup(&ds, 5, false);
        let pc = cf.parse().unwrap();
        let p = CompressedPredictor::new(pc).unwrap();
        for row in (0..ds.num_rows()).step_by(211) {
            let expect = f.predict_regression(&ds, row);
            match p.predict_row(&ds, row).unwrap() {
                PredictOne::Value(v) => {
                    assert_eq!(v.to_bits(), expect.to_bits(), "row {row}: bit-exact")
                }
                _ => panic!(),
            }
        }
    }

    #[test]
    fn two_class_arith_path_predictions() {
        let ds = synthetic::airfoil_classification(23);
        let (f, cf) = setup(&ds, 6, true);
        let pc = cf.parse().unwrap();
        let p = CompressedPredictor::new(pc).unwrap();
        for row in (0..ds.num_rows()).step_by(173) {
            let expect = f.predict_class(&ds, row);
            assert_eq!(p.predict_row(&ds, row).unwrap(), PredictOne::Class(expect));
        }
    }

    #[test]
    fn batch_matches_per_row_and_original() {
        let ds = synthetic::wages(24);
        let (f, cf) = setup(&ds, 8, true);
        let pc = cf.parse().unwrap();
        let p = CompressedPredictor::new(pc).unwrap();
        let batch = p.predict_all(&ds).unwrap();
        let expect = f.predict_all(&ds);
        assert_eq!(batch, expect);
        if let Predictions::Classes(cs) = &batch {
            for row in (0..ds.num_rows()).step_by(61) {
                assert_eq!(
                    p.predict_row(&ds, row).unwrap(),
                    PredictOne::Class(cs[row])
                );
            }
        }
    }

    #[test]
    fn parallel_batch_matches_sequential() {
        let ds = synthetic::wages(27);
        let (f, cf) = setup(&ds, 12, true);
        let pc = cf.parse().unwrap();
        let p = CompressedPredictor::new(pc).unwrap();
        let seq = p.predict_all_workers(&ds, 1).unwrap();
        for w in [2, 3, 8] {
            assert_eq!(p.predict_all_workers(&ds, w).unwrap(), seq, "{w} workers");
        }
        assert_eq!(seq, f.predict_all(&ds));
        // builder-style configuration drives the default path
        let p = p.with_workers(4);
        assert_eq!(p.workers(), 4);
        assert_eq!(p.predict_all(&ds).unwrap(), seq);
    }

    #[test]
    fn flat_engine_matches_baseline_redecode() {
        let ds = synthetic::wages(28);
        let (f, cf) = setup(&ds, 6, true);
        let p = CompressedPredictor::new(cf.parse().unwrap()).unwrap();
        let flat = p.predict_all(&ds).unwrap();
        assert_eq!(flat, p.predict_all_baseline(&ds).unwrap());
        assert_eq!(flat, f.predict_all(&ds));
    }

    #[test]
    fn plan_cache_hits_and_stays_correct() {
        let ds = synthetic::iris(29);
        let (_, cf) = setup(&ds, 5, true);
        let cache = Arc::new(super::super::flat::PlanCache::default());
        let p = CompressedPredictor::new(cf.parse().unwrap())
            .unwrap()
            .with_plan_cache(cache.clone());
        let cold = p.predict_all(&ds).unwrap();
        assert_eq!(cache.stats().misses, 5, "one decode per tree");
        assert_eq!(cache.stats().hits, 0);
        let warm = p.predict_all(&ds).unwrap();
        assert_eq!(warm, cold, "cached plans must not change predictions");
        assert_eq!(cache.stats().hits, 5, "warm batch hits every plan");
        assert_eq!(cache.stats().misses, 5);

        // a budget too small to cache anything must stay transparent
        let ds2 = synthetic::airfoil_regression(30);
        let (f2, cf2) = setup(&ds2, 4, false);
        let tiny = Arc::new(super::super::flat::PlanCache::new(1));
        let p2 = CompressedPredictor::new(cf2.parse().unwrap())
            .unwrap()
            .with_plan_cache(tiny.clone());
        assert_eq!(p2.predict_all(&ds2).unwrap(), f2.predict_all(&ds2));
        assert_eq!(tiny.len(), 0, "nothing fits a 1-byte budget");
    }

    #[test]
    fn row_axis_matches_tree_axis_and_original() {
        // few trees + wide batch → the heuristic takes the row axis at high
        // worker counts; results must match the 1-worker (tree-order) run
        let ds = synthetic::airfoil_classification(31);
        let (f, cf) = setup(&ds, 3, true);
        let p = CompressedPredictor::new(cf.parse().unwrap()).unwrap();
        let seq = p.predict_all_workers(&ds, 1).unwrap();
        for w in [2, 8] {
            assert_eq!(p.predict_all_workers(&ds, w).unwrap(), seq, "{w} workers");
        }
        assert_eq!(seq, f.predict_all(&ds));
    }

    #[test]
    fn regression_batch_bit_identical_across_workers() {
        let ds = synthetic::airfoil_regression(32);
        let (_, cf) = setup(&ds, 5, false);
        let p = CompressedPredictor::new(cf.parse().unwrap()).unwrap();
        let seq = p.predict_all_workers(&ds, 1).unwrap();
        for w in [2, 3, 8] {
            match (&seq, &p.predict_all_workers(&ds, w).unwrap()) {
                (Predictions::Values(a), Predictions::Values(b)) => {
                    for (row, (x, y)) in a.iter().zip(b).enumerate() {
                        assert_eq!(x.to_bits(), y.to_bits(), "row {row}, {w} workers");
                    }
                }
                _ => panic!("regression forest must yield values"),
            }
        }
        // and the per-row prefix decode agrees bit-exactly too
        if let Predictions::Values(vs) = &seq {
            for row in (0..ds.num_rows()).step_by(211) {
                match p.predict_row(&ds, row).unwrap() {
                    PredictOne::Value(v) => assert_eq!(v.to_bits(), vs[row].to_bits()),
                    _ => panic!(),
                }
            }
        }
    }

    #[test]
    fn schema_mismatch_rejected() {
        let ds = synthetic::iris(25);
        let (_, cf) = setup(&ds, 3, true);
        let pc = cf.parse().unwrap();
        let p = CompressedPredictor::new(pc).unwrap();
        let other = synthetic::wages(25);
        assert!(p.check_schema(&other).is_err());
        assert!(p.predict_all(&other).is_err());
    }

    #[test]
    fn decompress_via_predictor() {
        let ds = synthetic::iris(26);
        let (f, cf) = setup(&ds, 4, true);
        let pc = cf.parse().unwrap();
        let p = CompressedPredictor::new(pc).unwrap();
        assert!(p.decompress().unwrap().identical(&f));
    }
}
