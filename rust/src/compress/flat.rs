//! The flat-tree batch execution engine.
//!
//! [`super::predict::CompressedPredictor::predict_row`] answers a single
//! observation with a prefix decode — optimal when the query is one row.
//! Batches are a different regime: the PR-1 batch path re-decoded every
//! tree's Huffman streams *per batch* into pointer-linked
//! [`crate::forest::Tree`] nodes and routed rows one at a time through
//! heap-chasing walks.
//! This module replaces that with:
//!
//! * [`FlatTree`] — a tree decoded **once** into struct-of-arrays form:
//!   parallel arrays of feature index, numeric threshold, categorical mask,
//!   left/right child offsets, and per-node fits. Children always sit at
//!   higher indices than their parent (preorder), so routing is a monotone
//!   walk over dense arrays instead of a pointer chase, and the working set
//!   for one step is a handful of cache lines.
//! * **Blocked row routing** — [`FlatTree::accumulate`] advances rows in
//!   blocks of [`BLOCK`] through the arrays, so the 8 lanes' loads overlap
//!   and the inner loop is simple enough for the optimizer to keep in
//!   registers (and vectorize the numeric-compare case).
//! * [`PlanCache`] — a bounded, byte-accounted LRU memoizing `FlatTree`s
//!   per `(model, tree)`, so repeated batches against a resident model skip
//!   the Huffman decode entirely. Hit/miss/eviction counters feed the
//!   server's `STATS` verb; the model store charges plan bytes against its
//!   `max_resident_bytes` budget and drops plans before it evicts models.
//!
//! Correctness contract: routing a row through a `FlatTree` reaches exactly
//! the leaf the prefix decode reaches, and batch aggregation folds fits in
//! tree order per row, so `predict_all` output is bit-identical to the
//! per-row path (asserted by the property suite at worker counts 1/2/8).

use super::container::{FitCodec, ParsedContainer};
use crate::coding::arith::ArithDecoder;
use crate::coding::bitio::BitReader;
use crate::coding::huffman::HuffmanDecoder;
use crate::data::{Column, Dataset};
use crate::model::keys::ContextKey;
use crate::zaks::TreeShape;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Rows routed together through one tree; the struct-of-arrays layout keeps
/// all per-lane state in registers at this width.
pub const BLOCK: usize = 8;

/// Default byte budget of a standalone [`PlanCache`] (stores with a
/// `max_resident_bytes` budget manage the cap themselves).
pub const DEFAULT_PLAN_CACHE_BYTES: u64 = 64 << 20;

/// Per-node fit payloads of a flat tree (one entry per node; only the leaf
/// entries are ever read, but internal fits arrive for free from the
/// skip-decode that keeps the streams in sync).
#[derive(Debug, Clone, PartialEq)]
pub enum FlatFits {
    /// Classification fits: one class label per node.
    Classes(Vec<u32>),
    /// Regression fits: one value per node.
    Values(Vec<f64>),
}

/// One tree decoded into branchless-routable parallel arrays.
///
/// Layout invariants:
/// * arrays all have `node_count()` entries, indexed in preorder;
/// * a leaf is its own left/right child (`left[i] == i`), so "is leaf" is a
///   single load and a stalled lane in a row block is a no-op step;
/// * children of an internal node are strictly greater than the node
///   (preorder), so every walk terminates.
#[derive(Debug, Clone, PartialEq)]
pub struct FlatTree {
    feature: Vec<u32>,
    threshold: Vec<f64>,
    mask: Vec<u64>,
    left: Vec<u32>,
    right: Vec<u32>,
    fits: FlatFits,
}

/// A borrowed column view for routing: the dataset's enum is matched once
/// per feature, not once per node visit.
#[derive(Clone, Copy)]
pub enum ColRef<'a> {
    /// A numeric column's values.
    Num(&'a [f64]),
    /// A categorical column's level indices.
    Cat(&'a [u32]),
}

/// Extract routing views for every feature column of a dataset.
pub fn col_refs(ds: &Dataset) -> Vec<ColRef<'_>> {
    ds.features
        .iter()
        .map(|f| match &f.column {
            Column::Numeric(v) => ColRef::Num(v),
            Column::Categorical { values, .. } => ColRef::Cat(values),
        })
        .collect()
}

impl FlatTree {
    /// Decode tree `t` of a parsed container into flat form — the same
    /// stream walk as the pipeline decoder, but writing struct-of-arrays
    /// instead of pointer-linked nodes.
    pub fn decode(
        pc: &ParsedContainer,
        t: usize,
        shape: &TreeShape,
        vn_decoders: &[HuffmanDecoder],
        split_decoders: &[Vec<HuffmanDecoder>],
        fit_decoders: &[HuffmanDecoder],
    ) -> Result<FlatTree> {
        let n = shape.node_count();
        let depths = shape.depths();
        let mut vars_r = BitReader::new(pc.tree_vars(t));
        let mut splits_r = BitReader::new(pc.tree_splits(t));
        let mut fits_r = BitReader::new(pc.tree_fits(t));
        let mut arith = match pc.fit_codec {
            FitCodec::Arith => Some(ArithDecoder::new(fits_r.clone())),
            FitCodec::Huffman | FitCodec::Raw64 => None,
        };

        let mut feature = vec![0u32; n];
        let mut threshold = vec![0.0f64; n];
        let mut mask = vec![0u64; n];
        let mut left = Vec::with_capacity(n);
        let mut right = Vec::with_capacity(n);
        let mut fits = if pc.classification {
            FlatFits::Classes(Vec::with_capacity(n))
        } else {
            FlatFits::Values(Vec::with_capacity(n))
        };
        let mut father_feat: Vec<Option<u32>> = vec![None; n];

        for i in 0..n {
            let key = pc
                .conditioning
                .project(ContextKey::new(depths[i], father_feat[i]));
            // fit first — the encoder wrote one per node in preorder
            match (&mut arith, pc.fit_codec) {
                (Some(dec), FitCodec::Arith) => {
                    let cl = *pc.fit_map.get(&key).context("fit cluster missing")?;
                    let model = pc
                        .fit_models
                        .get(cl as usize)
                        .context("fit cluster id out of range")?;
                    let sym = dec.decode(model)?;
                    match &mut fits {
                        FlatFits::Classes(cs) => cs.push(sym),
                        FlatFits::Values(_) => bail!("arith fits in a regression container"),
                    }
                }
                (None, FitCodec::Huffman) => {
                    let cl = *pc.fit_map.get(&key).context("fit cluster missing")?;
                    let sym = fit_decoders
                        .get(cl as usize)
                        .context("fit cluster id out of range")?
                        .decode(&mut fits_r)?;
                    match &mut fits {
                        FlatFits::Classes(cs) => cs.push(sym),
                        FlatFits::Values(vs) => vs.push(
                            *pc.alphabets
                                .fits
                                .get(sym as usize)
                                .context("fit symbol out of table")?,
                        ),
                    }
                }
                (None, FitCodec::Raw64) => {
                    let v = pc
                        .fit_raw_codec
                        .as_ref()
                        .context("raw codec missing")?
                        .decode(&mut fits_r)?;
                    match &mut fits {
                        FlatFits::Values(vs) => vs.push(v),
                        FlatFits::Classes(_) => bail!("raw fits in a classification container"),
                    }
                }
                _ => unreachable!(),
            }
            match shape.children[i] {
                Some((l, r)) => {
                    let vcl = *pc.vn_map.get(&key).context("vn cluster missing")?;
                    let f = vn_decoders
                        .get(vcl as usize)
                        .context("vn cluster id out of range")?
                        .decode(&mut vars_r)?;
                    if f as usize >= pc.features.len() {
                        bail!("decoded feature {f} out of range");
                    }
                    let scl = *pc.split_maps[f as usize]
                        .get(&key)
                        .context("split cluster missing")?;
                    let sym = split_decoders[f as usize]
                        .get(scl as usize)
                        .context("split cluster id out of range")?
                        .decode(&mut splits_r)?;
                    let alpha = &pc.alphabets.splits[f as usize];
                    if sym as usize >= alpha.len() {
                        bail!("split symbol {sym} out of alphabet");
                    }
                    feature[i] = f;
                    match alpha.value_of(sym) {
                        crate::forest::SplitValue::Numeric(v) => threshold[i] = v,
                        crate::forest::SplitValue::Categorical(m) => mask[i] = m,
                    }
                    left.push(l);
                    right.push(r);
                    father_feat[l as usize] = Some(f);
                    father_feat[r as usize] = Some(f);
                }
                None => {
                    // leaf: self-loop makes routing idempotent
                    left.push(i as u32);
                    right.push(i as u32);
                }
            }
        }
        Ok(FlatTree { feature, threshold, mask, left, right, fits })
    }

    /// Number of nodes in this flat tree.
    pub fn node_count(&self) -> usize {
        self.left.len()
    }

    /// Heap bytes this plan keeps resident (the plan cache's accounting
    /// unit; `size_of::<FlatTree>` itself rides inside the cache entry).
    pub fn heap_bytes(&self) -> u64 {
        let n = self.node_count() as u64;
        let fit_bytes = match &self.fits {
            FlatFits::Classes(cs) => cs.len() as u64 * 4,
            FlatFits::Values(vs) => vs.len() as u64 * 8,
        };
        n * (4 + 8 + 8 + 4 + 4) + fit_bytes
    }

    /// The per-node fit payloads.
    pub fn fits(&self) -> &FlatFits {
        &self.fits
    }

    #[inline(always)]
    fn go_left(&self, cols: &[ColRef], n: usize, row: usize) -> bool {
        match cols[self.feature[n] as usize] {
            ColRef::Num(v) => v[row] <= self.threshold[n],
            ColRef::Cat(v) => self.mask[n] >> v[row] & 1 == 1,
        }
    }

    /// Route one row to its leaf index.
    pub fn route_row(&self, cols: &[ColRef], row: usize) -> usize {
        let mut n = 0usize;
        loop {
            let l = self.left[n] as usize;
            if l == n {
                return n;
            }
            n = if self.go_left(cols, n, row) { l } else { self.right[n] as usize };
        }
    }

    /// Route rows `rows` in blocks of [`BLOCK`] and fold each reached leaf's
    /// fit into the accumulators: classification increments
    /// `votes[(row - rows.start) * k + class]`, regression adds onto
    /// `sums[row - rows.start]`. Accumulator slices are relative to
    /// `rows.start` so row-parallel workers own disjoint dense slices.
    pub fn accumulate(
        &self,
        cols: &[ColRef],
        rows: Range<usize>,
        k: usize,
        votes: &mut [u32],
        sums: &mut [f64],
    ) -> Result<()> {
        let base = rows.start;
        let mut cur = [0u32; BLOCK];
        let mut start = rows.start;
        while start < rows.end {
            let len = BLOCK.min(rows.end - start);
            cur[..len].fill(0);
            // advance all lanes until every one sits on a self-looped leaf;
            // the walk is monotone (children > parent), so this terminates
            loop {
                let mut moved = false;
                for (lane, c) in cur[..len].iter_mut().enumerate() {
                    let n = *c as usize;
                    let l = self.left[n];
                    if l as usize == n {
                        continue;
                    }
                    moved = true;
                    *c = if self.go_left(cols, n, start + lane) { l } else { self.right[n] };
                }
                if !moved {
                    break;
                }
            }
            match &self.fits {
                FlatFits::Classes(cs) => {
                    for (lane, c) in cur[..len].iter().enumerate() {
                        let class = cs[*c as usize] as usize;
                        if class >= k {
                            bail!("decoded class {class} out of range (< {k})");
                        }
                        votes[(start + lane - base) * k + class] += 1;
                    }
                }
                FlatFits::Values(vs) => {
                    for (lane, c) in cur[..len].iter().enumerate() {
                        sums[start + lane - base] += vs[*c as usize];
                    }
                }
            }
            start += len;
        }
        Ok(())
    }
}

// ------------------------------------------------------------- plan cache

/// Counters and residency of a [`PlanCache`] (surfaced through the store's
/// `STATS` verb as `plan_hits`/`plan_misses`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PlanStats {
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Lookups that had to decode a tree.
    pub misses: u64,
    /// Plans dropped to fit the byte budget.
    pub evictions: u64,
    /// Decoded plan bytes currently resident.
    pub resident_bytes: u64,
    /// Number of plans currently resident.
    pub plans: u64,
}

struct PlanEntry {
    plan: Arc<FlatTree>,
    bytes: u64,
    last_used: u64,
}

struct PlanCacheInner {
    plans: HashMap<(u64, u32), PlanEntry>,
    bytes: u64,
    clock: u64,
    /// Model ids whose plans were purged ([`PlanCache::purge_model`]). An
    /// in-flight batch may still hold the retired model's predictor and
    /// miss-build its plans; admission rejects those so a dead model can
    /// never repopulate the cache (8 bytes per retired id, negligible).
    retired: std::collections::HashSet<u64>,
}

/// A bounded, byte-accounted LRU of decoded [`FlatTree`]s keyed by
/// `(model, tree)`.
///
/// The model key is [`ParsedContainer::plan_id`] — unique per parse and
/// never reused, so a stale entry can never alias a different model.
/// Lookups take one short mutex hold; decoding on a miss runs *outside*
/// the lock (two racing builders keep the first inserted plan). A plan
/// larger than the whole budget is returned uncached.
pub struct PlanCache {
    max_bytes: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    /// Cumulative µs spent inside miss-path plan builds (the traced
    /// predict path reads before/after deltas of this).
    build_us: AtomicU64,
    evictions: AtomicU64,
    inner: Mutex<PlanCacheInner>,
}

impl PlanCache {
    /// An empty cache capped at `max_bytes` of decoded plans.
    pub fn new(max_bytes: u64) -> Self {
        PlanCache {
            max_bytes: AtomicU64::new(max_bytes),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            build_us: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            inner: Mutex::new(PlanCacheInner {
                plans: HashMap::new(),
                bytes: 0,
                clock: 0,
                retired: std::collections::HashSet::new(),
            }),
        }
    }

    /// Current `(hits, misses)` totals — two relaxed atomic loads, cheap
    /// enough for per-call before/after deltas (the traced predict path
    /// attributes plan-cache traffic to request spans this way).
    pub fn counts(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Cumulative µs spent building plans on cache misses (same
    /// delta-friendly contract as [`Self::counts`]).
    pub fn build_us(&self) -> u64 {
        self.build_us.load(Ordering::Relaxed)
    }

    /// Fetch the plan for `(model, tree)`, building (and caching, budget
    /// permitting) on a miss.
    pub fn get_or_build(
        &self,
        model: u64,
        tree: u32,
        build: impl FnOnce() -> Result<FlatTree>,
    ) -> Result<Arc<FlatTree>> {
        {
            let mut g = self.inner.lock().unwrap();
            g.clock += 1;
            let now = g.clock;
            if let Some(e) = g.plans.get_mut(&(model, tree)) {
                e.last_used = now;
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(e.plan.clone());
            }
        }
        // decode outside the lock: a slow miss must not serialize every
        // other model's lookups behind it
        let t_build = std::time::Instant::now();
        let plan = Arc::new(build()?);
        self.build_us
            .fetch_add(t_build.elapsed().as_micros() as u64, Ordering::Relaxed);
        self.misses.fetch_add(1, Ordering::Relaxed);
        let bytes = plan.heap_bytes() + std::mem::size_of::<FlatTree>() as u64;
        if bytes > self.max_bytes.load(Ordering::Relaxed) {
            return Ok(plan); // bigger than the whole budget: serve uncached
        }
        let mut g = self.inner.lock().unwrap();
        if g.retired.contains(&model) {
            // the model was purged while we were decoding (replaced or
            // evicted); serve the plan but never cache under a dead id
            return Ok(plan);
        }
        g.clock += 1;
        let now = g.clock;
        match g.plans.entry((model, tree)) {
            std::collections::hash_map::Entry::Occupied(mut o) => {
                // raced with another builder for the same plan; keep theirs
                o.get_mut().last_used = now;
                return Ok(o.get().plan.clone());
            }
            std::collections::hash_map::Entry::Vacant(v) => {
                v.insert(PlanEntry { plan: plan.clone(), bytes, last_used: now });
                g.bytes += bytes;
            }
        }
        let max = self.max_bytes.load(Ordering::Relaxed);
        self.evict_locked(&mut g, max);
        Ok(plan)
    }

    /// Evict least-recently-used plans until residency fits `target`.
    /// One pass + sort instead of a min-scan per victim: bulk shrinks (the
    /// store rebalancing its budget on every insert) stay O(n log n) under
    /// the lock rather than O(n) per evicted plan.
    fn evict_locked(&self, g: &mut PlanCacheInner, target: u64) {
        if g.bytes <= target {
            return;
        }
        let mut order: Vec<((u64, u32), u64, u64)> = g
            .plans
            .iter()
            .map(|(&key, e)| (key, e.last_used, e.bytes))
            .collect();
        order.sort_unstable_by_key(|&(_, used, _)| used);
        for (key, _, bytes) in order {
            if g.bytes <= target {
                break;
            }
            g.plans.remove(&key);
            g.bytes -= bytes;
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Shrink residency to at most `target` bytes (LRU-first). The store's
    /// budget enforcement drops plans this way before evicting any model.
    pub fn shrink_to(&self, target: u64) {
        let mut g = self.inner.lock().unwrap();
        self.evict_locked(&mut g, target);
    }

    /// Reset the byte budget (and shrink if already past it).
    pub fn set_max_bytes(&self, max_bytes: u64) {
        self.max_bytes.store(max_bytes, Ordering::Relaxed);
        self.shrink_to(max_bytes);
    }

    /// The current byte budget.
    pub fn max_bytes(&self) -> u64 {
        self.max_bytes.load(Ordering::Relaxed)
    }

    /// Drop every plan belonging to `model` (the store calls this when a
    /// model is removed, evicted, or replaced) and retire the id, so an
    /// in-flight batch still holding the dead model's predictor cannot
    /// repopulate the cache with unservable plans. Returns the bytes freed.
    pub fn purge_model(&self, model: u64) -> u64 {
        let mut g = self.inner.lock().unwrap();
        g.retired.insert(model);
        let victims: Vec<(u64, u32)> =
            g.plans.keys().filter(|(m, _)| *m == model).copied().collect();
        let mut freed = 0;
        for key in victims {
            if let Some(e) = g.plans.remove(&key) {
                g.bytes -= e.bytes;
                freed += e.bytes;
            }
        }
        freed
    }

    /// Decoded plan bytes currently resident.
    pub fn resident_bytes(&self) -> u64 {
        self.inner.lock().unwrap().bytes
    }

    /// Number of plans currently resident.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().plans.len()
    }

    /// Whether the cache holds no plans.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of the cache counters and residency.
    pub fn stats(&self) -> PlanStats {
        let g = self.inner.lock().unwrap();
        PlanStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            resident_bytes: g.bytes,
            plans: g.plans.len() as u64,
        }
    }
}

impl Default for PlanCache {
    fn default() -> Self {
        Self::new(DEFAULT_PLAN_CACHE_BYTES)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::pipeline::{CompressOptions, CompressedForest};
    use crate::data::synthetic;
    use crate::forest::{Fit, Forest, ForestParams};

    fn flat_trees_of(cf: &CompressedForest) -> (ParsedContainer, Vec<FlatTree>) {
        let pc = cf.parse().unwrap();
        let seqs = crate::zaks::split_concatenated(&pc.zaks_bits, pc.n_trees).unwrap();
        let vn: Vec<_> = pc.vn_dicts.iter().map(|d| d.decoder()).collect();
        let sd: Vec<Vec<_>> = pc
            .split_dicts
            .iter()
            .map(|per| per.iter().map(|d| d.decoder()).collect())
            .collect();
        let fd: Vec<_> = pc.fit_dicts.iter().map(|d| d.decoder()).collect();
        let flats = (0..pc.n_trees)
            .map(|t| {
                let shape = crate::zaks::shape_from_zaks(&seqs[t]).unwrap();
                FlatTree::decode(&pc, t, &shape, &vn, &sd, &fd).unwrap()
            })
            .collect();
        (pc, flats)
    }

    #[test]
    fn flat_routing_matches_tree_walk() {
        for (ds, classification) in [
            (synthetic::iris(41), true),
            (synthetic::wages(42), true),
            (synthetic::airfoil_regression(43), false),
        ] {
            let params = if classification {
                ForestParams::classification(5)
            } else {
                ForestParams::regression(5)
            };
            let forest = Forest::train(&ds, &params, 11);
            let cf =
                CompressedForest::compress(&forest, &ds, &CompressOptions::default()).unwrap();
            let (_, flats) = flat_trees_of(&cf);
            let cols = col_refs(&ds);
            for (t, flat) in flats.iter().enumerate() {
                assert!(flat.node_count() > 0);
                assert!(flat.heap_bytes() > 0);
                for row in (0..ds.num_rows()).step_by(17) {
                    let leaf = flat.route_row(&cols, row);
                    let expect = forest.trees[t].predict_row(&ds, row);
                    match (flat.fits(), expect) {
                        (FlatFits::Classes(cs), Fit::Class(c)) => {
                            assert_eq!(cs[leaf], c, "tree {t} row {row}")
                        }
                        (FlatFits::Values(vs), Fit::Regression(v)) => {
                            assert_eq!(vs[leaf].to_bits(), v.to_bits(), "tree {t} row {row}")
                        }
                        _ => panic!("fit kind mismatch"),
                    }
                }
            }
        }
    }

    #[test]
    fn blocked_accumulate_matches_per_row_routing() {
        let ds = synthetic::wages(44);
        let forest = Forest::train(&ds, &ForestParams::classification(4), 12);
        let cf = CompressedForest::compress(&forest, &ds, &CompressOptions::default()).unwrap();
        let (pc, flats) = flat_trees_of(&cf);
        let cols = col_refs(&ds);
        let k = pc.classes as usize;
        // ragged range (not a BLOCK multiple, nonzero start) through every tree
        let rows = 3..ds.num_rows().min(3 + 2 * BLOCK + 5);
        let mut votes = vec![0u32; rows.len() * k];
        let mut sums = Vec::new();
        for flat in &flats {
            flat.accumulate(&cols, rows.clone(), k, &mut votes, &mut sums).unwrap();
        }
        for (i, row) in rows.clone().enumerate() {
            for (c, &v) in votes[i * k..(i + 1) * k].iter().enumerate() {
                let expect = flats
                    .iter()
                    .filter(|f| match f.fits() {
                        FlatFits::Classes(cs) => cs[f.route_row(&cols, row)] == c as u32,
                        _ => false,
                    })
                    .count() as u32;
                assert_eq!(v, expect, "row {row} class {c}");
            }
        }
    }

    #[test]
    fn leaf_only_trees_are_self_loops() {
        let mut g = crate::testing::prop::Gen::new(7);
        let ds = g.dataset(10, 1, 1, true);
        let forest = g.leaf_only_forest(&ds, 3);
        let cf = CompressedForest::compress(&forest, &ds, &CompressOptions::default()).unwrap();
        let (_, flats) = flat_trees_of(&cf);
        let cols = col_refs(&ds);
        for flat in &flats {
            assert_eq!(flat.node_count(), 1);
            assert_eq!(flat.route_row(&cols, 0), 0);
        }
    }

    fn tiny_plan(nodes: usize) -> FlatTree {
        FlatTree {
            feature: vec![0; nodes],
            threshold: vec![0.0; nodes],
            mask: vec![0; nodes],
            left: (0..nodes as u32).collect(),
            right: (0..nodes as u32).collect(),
            fits: FlatFits::Classes(vec![0; nodes]),
        }
    }

    #[test]
    fn plan_cache_hits_misses_and_lru_eviction() {
        let one = tiny_plan(4).heap_bytes() + std::mem::size_of::<FlatTree>() as u64;
        let cache = PlanCache::new(2 * one); // room for exactly two plans
        for t in 0..2u32 {
            cache.get_or_build(1, t, || Ok(tiny_plan(4))).unwrap();
        }
        assert_eq!(cache.stats().misses, 2);
        assert_eq!(cache.len(), 2);
        // touch plan 0 so plan 1 is LRU, then insert a third
        cache.get_or_build(1, 0, || panic!("must hit")).unwrap();
        assert_eq!(cache.stats().hits, 1);
        cache.get_or_build(1, 2, || Ok(tiny_plan(4))).unwrap();
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().evictions, 1);
        cache.get_or_build(1, 0, || panic!("plan 0 must survive")).unwrap();
        // plan 1 was evicted: rebuilding it counts a miss
        cache.get_or_build(1, 1, || Ok(tiny_plan(4))).unwrap();
        assert_eq!(cache.stats().misses, 4);
        assert!(cache.resident_bytes() <= cache.max_bytes());
    }

    #[test]
    fn plan_cache_oversized_plan_served_uncached() {
        let cache = PlanCache::new(8); // smaller than any real plan
        let plan = cache.get_or_build(1, 0, || Ok(tiny_plan(64))).unwrap();
        assert_eq!(plan.node_count(), 64);
        assert_eq!(cache.len(), 0, "oversized plans must not enter the cache");
        assert_eq!(cache.stats().misses, 1);
    }

    #[test]
    fn purged_model_id_cannot_repopulate_the_cache() {
        let cache = PlanCache::new(u64::MAX);
        cache.get_or_build(5, 0, || Ok(tiny_plan(4))).unwrap();
        assert_eq!(cache.len(), 1);
        cache.purge_model(5);
        assert_eq!(cache.len(), 0);
        // an in-flight batch still holding the dead model's predictor
        // miss-builds the plan; it must be served but never cached
        let plan = cache.get_or_build(5, 0, || Ok(tiny_plan(4))).unwrap();
        assert_eq!(plan.node_count(), 4);
        assert_eq!(cache.len(), 0, "retired ids never re-enter the cache");
        assert_eq!(cache.resident_bytes(), 0);
        // other models are unaffected
        cache.get_or_build(6, 0, || Ok(tiny_plan(4))).unwrap();
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn plan_cache_purge_and_shrink() {
        let cache = PlanCache::new(u64::MAX);
        for t in 0..3u32 {
            cache.get_or_build(7, t, || Ok(tiny_plan(4))).unwrap();
            cache.get_or_build(8, t, || Ok(tiny_plan(4))).unwrap();
        }
        assert_eq!(cache.len(), 6);
        let freed = cache.purge_model(7);
        assert!(freed > 0);
        assert_eq!(cache.len(), 3);
        // model 8 untouched
        cache.get_or_build(8, 0, || panic!("must hit")).unwrap();
        cache.shrink_to(0);
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.resident_bytes(), 0);
        // set_max_bytes enforces immediately
        for t in 0..3u32 {
            cache.get_or_build(9, t, || Ok(tiny_plan(4))).unwrap();
        }
        let one = tiny_plan(4).heap_bytes() + std::mem::size_of::<FlatTree>() as u64;
        cache.set_max_bytes(one);
        assert!(cache.resident_bytes() <= one);
        assert_eq!(cache.len(), 1);
    }
}
