//! `repro` — the rf-compress command-line coordinator.
//!
//! Subcommands:
//!
//! ```text
//! repro compress   --dataset <key> [--trees N] [--seed S] [--out FILE]
//!                  [--k-max K] [--fit-alpha-bits 64] [--native]
//!                  [--struct-chain C] [--split-chain C] [--fit-chain C]
//! repro verify     --in FILE --dataset <key> [--trees N] [--seed S]
//! repro lossy      --dataset <key> [--trees N] [--bits B] [--keep N0]
//! repro sweep-stages --dataset <key> [--trees N] [--quick]
//!                  [--out BENCH_stages.json] [--tolerance 0.4]
//! repro serve      --port P [--dataset <key>[,<key>...]] [--pack FILE|DIR,...]
//!                  [--trees N] [--inflight-cap N] [--request-timeout-ms MS]
//! repro pack       build|list|extract               # RFPK model packs
//! repro pack       init|append|remove|compact       # mutable generation chains
//! repro suite      [--trees N] [--paper-scale]      # Table-2 style report
//! repro datasets                                    # list dataset keys
//! ```
//!
//! Dataset keys are the Table-2 rows (`iris`, `wages`, `airfoil+`,
//! `airfoil*`, `bike+`, `naval+`, `naval*`, `shuttle`, `forests`, `adults`,
//! `liberty+`, `liberty*`, `otto`) or a CSV path via `--csv FILE
//! --target-col I [--target-kind reg|cls]`.

use rf_compress::compress::{CompressOptions, CompressedForest};
use rf_compress::coordinator::server::{Server, ServerConfig};
use rf_compress::coordinator::store::ModelStore;
use rf_compress::coordinator::Coordinator;
use rf_compress::data::synthetic::table2_suite;
use rf_compress::data::Dataset;
use rf_compress::lossy;
use rf_compress::util::cli::Args;
use rf_compress::util::stats::human_bytes;
use std::sync::Arc;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional(0).unwrap_or("help").to_string();
    let code = match cmd.as_str() {
        "compress" => cmd_compress(&args),
        "verify" => cmd_verify(&args),
        "lossy" => cmd_lossy(&args),
        "serve" => cmd_serve(&args),
        "loadgen" => cmd_loadgen(&args),
        "pack" => cmd_pack(&args),
        "suite" => cmd_suite(&args),
        "sweep-stages" => cmd_sweep_stages(&args),
        "bench-gate" => cmd_bench_gate(&args),
        "datasets" => {
            for e in table2_suite() {
                println!("{}", e.key);
            }
            0
        }
        _ => {
            eprintln!("{}", HELP);
            if cmd == "help" {
                0
            } else {
                2
            }
        }
    };
    std::process::exit(code);
}

const HELP: &str = "repro — lossless (and lossy) random-forest compression
  compress   --dataset KEY [--trees N] [--seed S] [--out FILE] [--native]
             [--struct-chain C] [--split-chain C] [--fit-chain C]
             (C is a stage chain like delta+lzss; see README)
  verify     --in FILE --dataset KEY [--trees N] [--seed S]
  lossy      --dataset KEY [--trees N] [--bits B] [--keep N0]
  sweep-stages --dataset KEY [--trees N] [--seed S] [--quick]
             [--out BENCH_stages.json] [--tolerance 0.4]
  serve      --port P [--dataset KEY[,KEY...]] [--pack FILE|CHAINDIR[,...]]
             [--trees N] [--max-resident-bytes B] [--predict-workers W]
             [--plan-cache-bytes B] [--spill-dir DIR] [--spill-bytes B]
             [--admission lru|tinylfu]
             [--inflight-cap N] [--request-timeout-ms MS]
             [--slow-threshold-us US] [--trace-ring N]
             [--compact-generations N] [--compact-tombstone-ratio R]
  serve      --route --backends H:P[,H:P...] [--port P] [--replication R]
             [--hot-k K] [--max-tries N] [--probe-interval-ms MS]
             [--request-timeout-ms MS] [--inflight-cap N]
             [--slow-threshold-us US] [--trace-ring N]
  loadgen    [--scenario NAME[,NAME...]|all] [--seed S] [--quick]
             [--tenants N] [--requests N] [--rate RPS] [--zipf-s Z]
             [--hot-set K] [--cohort C] [--admission lru|tinylfu]
             [--compare-admission] [--serial] [--window N]
             [--dataset KEY] [--trees N] [--max-resident-bytes B]
             [--spill-dir DIR] [--out BENCH_loadgen.json]
             [--trace-only] [--trace-out FILE]
             [--addr H:P --models M[,M...] --values V1,V2,...]
             (scenarios: steady diurnal flash_crowd scan cohort_burst;
              see rust/OPERATIONS.md)
  pack build   --out FILE (--inputs A.rfcz[,B.rfcz...] |
                           --dataset KEY --members N [--trees T])
               [--no-shared] [--seed S]
  pack list    (--in FILE | --chain DIR)
  pack extract --in FILE (--key K --out FILE | --out-dir DIR)
  pack init    --chain DIR
  pack append  --chain DIR (--inputs A.rfcz[,...] |
                            --dataset KEY --members N [--key-offset O])
  pack remove  --chain DIR --keys K[,K...]
  pack compact --chain DIR [--dataset KEY]   (--dataset re-shares codebooks)
  suite      [--trees N] [--paper-scale]
  bench-gate --baseline FILE --current FILE [--tolerance 0.25]
  bench-gate --current FILE --write-baseline [--baseline FILE]
  datasets";

fn load_dataset(args: &Args) -> Option<Dataset> {
    if let Some(csv) = args.get("csv") {
        let col: usize = args.get_or("target-col", 0);
        let kind = args.get("target-kind").unwrap_or("reg");
        let spec = if kind == "cls" {
            rf_compress::data::csv::TargetSpec::Classification(col)
        } else {
            rf_compress::data::csv::TargetSpec::Regression(col)
        };
        return match rf_compress::data::csv::load_csv(std::path::Path::new(csv), spec) {
            Ok(ds) => Some(ds),
            Err(e) => {
                eprintln!("error loading {csv}: {e:#}");
                None
            }
        };
    }
    let key = args.get("dataset")?;
    dataset_by_key(key, args.get_or("data-seed", 1234u64))
}

fn dataset_by_key(key: &str, seed: u64) -> Option<Dataset> {
    table2_suite()
        .into_iter()
        .find(|e| e.key == key)
        .map(|e| (e.make)(seed))
        .or_else(|| {
            eprintln!("unknown dataset {key:?}; see `repro datasets`");
            None
        })
}

/// Parse one `--<key> <chain>` stage-chain flag (`-`/absent → empty chain).
fn chain_arg(args: &Args, key: &str) -> Vec<rf_compress::coding::stage::StageSpec> {
    match args.get(key) {
        None => Vec::new(),
        Some(s) => match rf_compress::coding::stage::parse_chain(s) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("--{key} {s:?}: {e:#}");
                std::process::exit(2);
            }
        },
    }
}

fn opts_from(args: &Args) -> CompressOptions {
    CompressOptions {
        k_max: args.get_or("k-max", 10usize),
        seed: args.get_or("seed", 0x5eedu64),
        workers: args.get_or("workers", rf_compress::util::threads::default_workers()),
        conditioning: rf_compress::model::ModelConditioning::DepthFather,
        fit_alpha_bits: args.get_or("fit-alpha-bits", 64u32),
        dataset_indexed_splits: args.flag("paper-accounting"),
        chains: rf_compress::coding::stage::SectionChains {
            structure: chain_arg(args, "struct-chain"),
            split_tables: chain_arg(args, "split-chain"),
            fit_table: chain_arg(args, "fit-chain"),
        },
    }
}

fn coordinator(args: &Args) -> Coordinator {
    if args.flag("native") {
        Coordinator::native_only()
    } else {
        Coordinator::new()
    }
}

fn cmd_compress(args: &Args) -> i32 {
    let Some(ds) = load_dataset(args) else { return 2 };
    let trees = args.get_or("trees", 100usize);
    let seed = args.get_or("seed", 7u64);
    let mut coord = coordinator(args);
    println!("engine: {}", coord.engine_name());
    let (forest, cf, report) = match coord.train_and_compress(&ds, trees, seed, &opts_from(args)) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("compression failed: {e:#}");
            return 1;
        }
    };
    print_report(&report);
    // verify losslessness before declaring success
    let restored = if opts_from(args).dataset_indexed_splits {
        cf.decompress_with_dataset(&ds)
    } else {
        cf.decompress()
    };
    match restored {
        Ok(restored) if restored.identical(&forest) => println!("lossless: VERIFIED"),
        Ok(_) => {
            eprintln!("lossless check FAILED: decompressed forest differs");
            return 1;
        }
        Err(e) => {
            eprintln!("decompression failed: {e:#}");
            return 1;
        }
    }
    if let Some(out) = args.get("out") {
        if let Err(e) = std::fs::write(out, &cf.bytes) {
            eprintln!("write {out}: {e}");
            return 1;
        }
        println!("wrote {out} ({})", human_bytes(cf.total_bytes()));
    }
    0
}

fn cmd_verify(args: &Args) -> i32 {
    let Some(path) = args.get("in") else {
        eprintln!("verify needs --in FILE");
        return 2;
    };
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("read {path}: {e}");
            return 1;
        }
    };
    let cf = match CompressedForest::from_bytes(bytes) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("parse: {e:#}");
            return 1;
        }
    };
    let forest = match cf.decompress() {
        Ok(f) => f,
        Err(e) => {
            eprintln!("decompress: {e:#}");
            return 1;
        }
    };
    println!(
        "container OK: {} trees, {} nodes, mean depth {:.1}, {}",
        forest.num_trees(),
        forest.total_nodes(),
        forest.mean_depth(),
        human_bytes(cf.total_bytes())
    );
    // optional: retrain and compare
    if args.get("dataset").is_some() {
        let Some(ds) = load_dataset(args) else { return 2 };
        let trees = args.get_or("trees", 100usize);
        let seed = args.get_or("seed", 7u64);
        let coord = coordinator(args);
        let retrained = coord.train(&ds, trees, seed);
        if retrained.identical(&forest) {
            println!("matches retrained forest: LOSSLESS");
        } else {
            eprintln!("retrained forest differs (wrong --trees/--seed/--dataset?)");
            return 1;
        }
    }
    0
}

fn cmd_lossy(args: &Args) -> i32 {
    let Some(ds) = load_dataset(args) else { return 2 };
    if ds.target.is_classification() {
        eprintln!("lossy quantization targets regression datasets (use a `+` key)");
        return 2;
    }
    let trees = args.get_or("trees", 100usize);
    let bits = args.get_or("bits", 7u32);
    let keep = args.get_or("keep", trees / 4);
    let mut rng = rf_compress::util::Pcg64::new(args.get_or("seed", 7u64));
    let tt = ds.train_test_split(0.8, &mut rng);
    let mut coord = coordinator(args);
    let forest = coord.train(&tt.train, trees, args.get_or("seed", 7u64));
    let full_mse = forest.test_error(&tt.test);
    let opts = opts_from(args);

    let (cf_full, _) = coord.run_job(&tt.train, &forest, &opts, 0.0).map_or_else(
        |e| {
            eprintln!("{e:#}");
            std::process::exit(1)
        },
        |x| x,
    );
    println!(
        "lossless: {} trees, test MSE {full_mse:.4}, size {}",
        forest.num_trees(),
        human_bytes(cf_full.total_bytes())
    );

    let (qforest, _) =
        lossy::quantize_fits(&forest, bits, lossy::QuantizeMethod::Uniform).unwrap();
    let sub = lossy::subsample_trees(&qforest, keep, 99);
    let (cf_lossy, _) = coord.run_job(&tt.train, &sub, &opts, 0.0).unwrap();
    let lossy_mse = sub.test_error(&tt.test);
    println!(
        "lossy ({bits}-bit fits, {keep} trees): test MSE {lossy_mse:.4}, size {}",
        human_bytes(cf_lossy.total_bytes())
    );
    println!(
        "gain {:.1}x, MSE ratio {:.3}",
        cf_full.total_bytes() as f64 / cf_lossy.total_bytes().max(1) as f64,
        lossy_mse / full_mse.max(1e-12)
    );
    0
}

fn cmd_serve(args: &Args) -> i32 {
    if args.flag("route") {
        return cmd_serve_route(args);
    }
    let keys = args.get_list::<String>("dataset").unwrap_or_default();
    let packs = args.get_list::<String>("pack").unwrap_or_default();
    if keys.is_empty() && packs.is_empty() {
        eprintln!("serve needs --dataset KEY[,KEY...] and/or --pack FILE[,FILE...]");
        return 2;
    }
    let trees = args.get_or("trees", 50usize);
    let port: u16 = args.get_or("port", 7878u16);
    // storage-budget simulator (paper §1): optional resident-bytes cap with
    // LRU eviction, plus tree-parallel batch prediction
    let budget = match args.get("max-resident-bytes") {
        None => None,
        Some(s) => match s.parse::<u64>() {
            Ok(b) => Some(b),
            Err(_) => {
                eprintln!("serve: --max-resident-bytes expects a byte count, got {s:?}");
                return 2;
            }
        },
    };
    let workers = args.get_or(
        "predict-workers",
        rf_compress::util::threads::default_workers(),
    );
    let mut store =
        ModelStore::with_config(rf_compress::coordinator::store::DEFAULT_SHARDS, budget)
            .predict_workers(workers);
    // admission policy under budget pressure: recency-only (lru, default)
    // or frequency-weighted (tinylfu); see rust/OPERATIONS.md
    if let Some(s) = args.get("admission") {
        match rf_compress::coordinator::admission::AdmissionPolicy::parse(s) {
            Some(p) => store = store.admission(p),
            None => {
                eprintln!("serve: --admission expects lru or tinylfu, got {s:?}");
                return 2;
            }
        }
    }
    // disk tier: evictions spill container bytes here and reload via mmap
    let spill_dir = args.get("spill-dir").map(std::path::PathBuf::from);
    let spill_bytes = match args.get("spill-bytes") {
        None => None,
        Some(s) => match s.parse::<u64>() {
            Ok(b) => Some(b),
            Err(_) => {
                eprintln!("serve: --spill-bytes expects a byte count, got {s:?}");
                return 2;
            }
        },
    };
    if spill_bytes.is_some() && spill_dir.is_none() {
        eprintln!("serve: --spill-bytes needs --spill-dir");
        return 2;
    }
    if spill_dir.is_some() && budget.is_none() {
        eprintln!(
            "serve: note — --spill-dir without --max-resident-bytes never spills \
             automatically (nothing evicts); set a budget to activate the tier"
        );
    }
    if let Some(dir) = &spill_dir {
        store = store.spill_dir(dir.clone());
    }
    if let Some(b) = spill_bytes {
        store = store.spill_bytes(b);
    }
    // flat-plan cache cap for unbounded stores (budgeted stores size the
    // cache from whatever max-resident-bytes leaves after compressed bytes)
    if let Some(s) = args.get("plan-cache-bytes") {
        match s.parse::<u64>() {
            Ok(_) if budget.is_some() => {
                eprintln!(
                    "serve: --plan-cache-bytes is ignored when --max-resident-bytes is \
                     set (plans share the budget's slack); drop one of the two"
                );
            }
            Ok(b) => store = store.plan_cache_bytes(b),
            Err(_) => {
                eprintln!("serve: --plan-cache-bytes expects a byte count, got {s:?}");
                return 2;
            }
        }
    }
    // observability knobs: the slow-request retention threshold and the
    // trace-ring capacity behind the SLOW verb (see rust/OPERATIONS.md)
    if let Some(s) = args.get("slow-threshold-us") {
        match s.parse::<u64>() {
            Ok(us) => store = store.slow_threshold_us(us),
            Err(_) => {
                eprintln!("serve: --slow-threshold-us expects a microsecond count, got {s:?}");
                return 2;
            }
        }
    }
    if let Some(s) = args.get("trace-ring") {
        match s.parse::<usize>() {
            Ok(n) => store = store.trace_ring(n),
            Err(_) => {
                eprintln!("serve: --trace-ring expects a capacity, got {s:?}");
                return 2;
            }
        }
    }
    // store-side chain compaction triggers (see rust/OPERATIONS.md):
    // generation-count and tombstone-ratio thresholds over mounted chains
    if let Some(s) = args.get("compact-generations") {
        match s.parse::<usize>() {
            Ok(n) => store = store.compact_generations(n),
            Err(_) => {
                eprintln!("serve: --compact-generations expects a count, got {s:?}");
                return 2;
            }
        }
    }
    if let Some(s) = args.get("compact-tombstone-ratio") {
        match s.parse::<f64>() {
            Ok(r) => store = store.compact_tombstone_ratio(r),
            Err(_) => {
                eprintln!("serve: --compact-tombstone-ratio expects a ratio, got {s:?}");
                return 2;
            }
        }
    }
    let store = Arc::new(store);
    let mut coord = coordinator(args);
    for key in &keys {
        let Some(ds) = dataset_by_key(key, args.get_or("data-seed", 1234u64)) else {
            return 2;
        };
        let (_, cf, report) = coord
            .train_and_compress(&ds, trees, args.get_or("seed", 7u64), &opts_from(args))
            .unwrap();
        store.insert(key, &cf).unwrap();
        println!("loaded {key}: {}", human_bytes(report.ours_bytes));
    }
    // model packs mount as the third tier: members stay unloaded (and cost
    // no RAM) until their first request. A directory is a generation chain
    // (MANIFEST + gen-*.rfpk); a file is a single immutable archive.
    for path in &packs {
        let p = std::path::Path::new(path);
        if p.is_dir() {
            let chain = match rf_compress::pack::PackChain::open(p) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("chain {path}: {e:#}");
                    return 1;
                }
            };
            let cs = chain.stats();
            let (_handle, n) = match store.attach_chain(chain) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("chain {path}: {e:#}");
                    return 1;
                }
            };
            println!(
                "attached chain {path}: {n} live members across {} generation(s), \
                 {} tombstone(s), {} archive bytes",
                cs.generations,
                cs.tombstones,
                human_bytes(cs.archive_bytes)
            );
            continue;
        }
        let pack = match rf_compress::pack::PackArchive::open(p) {
            Ok(pa) => Arc::new(pa),
            Err(e) => {
                eprintln!("pack {path}: {e:#}");
                return 1;
            }
        };
        let n = match store.attach_pack(&pack) {
            Ok(n) => n,
            Err(e) => {
                eprintln!("pack {path}: {e:#}");
                return 1;
            }
        };
        println!(
            "attached pack {path}: {n} members, {} archive ({} blobs shared)",
            human_bytes(pack.archive_bytes()),
            pack.blob_count()
        );
    }
    // per-connection pipelining knobs: in-flight cap (ERR busy past it)
    // and the request timeout (typed ERR timeout, connection stays open)
    let mut server_cfg = ServerConfig::default();
    if let Some(s) = args.get("inflight-cap") {
        match s.parse::<usize>() {
            Ok(n) if n > 0 => server_cfg.inflight_cap = n,
            _ => {
                eprintln!("serve: --inflight-cap expects a positive count, got {s:?}");
                return 2;
            }
        }
    }
    if let Some(s) = args.get("request-timeout-ms") {
        match s.parse::<u64>() {
            // 0 would time every request out before its batch window
            // closes — reject it rather than serve nothing but errors
            Ok(ms) if ms > 0 => {
                server_cfg.request_timeout = std::time::Duration::from_millis(ms);
            }
            _ => {
                eprintln!(
                    "serve: --request-timeout-ms expects a positive millisecond \
                     count, got {s:?}"
                );
                return 2;
            }
        }
    }
    let server = match Server::start_with(store.clone(), port, server_cfg.clone()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("server: {e:#}");
            return 1;
        }
    };
    println!(
        "serving {} models ({} resident{}) on {}",
        store.len(),
        human_bytes(store.resident_bytes()),
        match store.max_resident_bytes() {
            Some(b) => format!(", budget {}", human_bytes(b)),
            None => String::new(),
        },
        server.addr()
    );
    println!(
        "plan cache: up to {} of decoded flat trees",
        human_bytes(store.plan_cache().max_bytes())
    );
    println!("admission policy: {}", store.admission_policy());
    if let Some(dir) = store.spill_path() {
        println!(
            "spill tier: {} ({})",
            dir.display(),
            match store.max_spill_bytes() {
                Some(b) => format!("budget {}", human_bytes(b)),
                None => "unbounded".to_string(),
            }
        );
    }
    if store.packed_len() > 0 {
        println!(
            "packed tier: {} members unloaded ({} when resident)",
            store.packed_len(),
            human_bytes(store.packed_bytes())
        );
    }
    println!(
        "protocol: PREDICT <model> <v1,v2,...> | PIPE <id> PREDICT ... | LIST | STATS \
         | BYTES | METRICS | SLOW | QUIT  (see rust/PROTOCOL.md)"
    );
    println!(
        "pipelining: up to {} in flight per connection, {} ms request timeout",
        server_cfg.inflight_cap,
        server_cfg.request_timeout.as_millis()
    );
    println!(
        "tracing: requests ≥ {} µs retained in a {}-entry SLOW ring",
        store.obs().slow_threshold_us(),
        store.obs().ring().capacity()
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// `repro serve --route`: start the shard-routing coordinator instead of a
/// backend. The router holds no models — it rendezvous-hashes model keys
/// across `--backends`, pools upstream connections, fails reads over across
/// the replica set, and ejects/re-admits backends per its health probes.
fn cmd_serve_route(args: &Args) -> i32 {
    use rf_compress::coordinator::router::{Router, RouterConfig};
    let backends: Vec<String> = args.get_list::<String>("backends").unwrap_or_default();
    if backends.is_empty() {
        eprintln!("serve --route needs --backends HOST:PORT[,HOST:PORT...]");
        return 2;
    }
    let mut addrs = Vec::new();
    for b in &backends {
        match b.parse::<std::net::SocketAddr>() {
            Ok(a) => addrs.push(a),
            Err(_) => {
                eprintln!("serve --route: bad backend address {b:?} (want HOST:PORT)");
                return 2;
            }
        }
    }
    let port: u16 = args.get_or("port", 7878u16);
    let base = RouterConfig::default();
    let mut cfg = RouterConfig {
        replication: args.get_or("replication", base.replication),
        hot_k: args.get_or("hot-k", base.hot_k),
        max_tries: args.get_or("max-tries", base.max_tries),
        ..base
    };
    if cfg.replication == 0 || cfg.max_tries == 0 {
        eprintln!("serve --route: --replication and --max-tries must be positive");
        return 2;
    }
    if let Some(s) = args.get("probe-interval-ms") {
        match s.parse::<u64>() {
            Ok(ms) if ms > 0 => {
                cfg.health.probe_interval = std::time::Duration::from_millis(ms);
            }
            _ => {
                eprintln!(
                    "serve --route: --probe-interval-ms expects a positive millisecond \
                     count, got {s:?}"
                );
                return 2;
            }
        }
    }
    if let Some(s) = args.get("request-timeout-ms") {
        match s.parse::<u64>() {
            Ok(ms) if ms > 0 => cfg.request_timeout = std::time::Duration::from_millis(ms),
            _ => {
                eprintln!(
                    "serve --route: --request-timeout-ms expects a positive millisecond \
                     count, got {s:?}"
                );
                return 2;
            }
        }
    }
    if let Some(s) = args.get("inflight-cap") {
        match s.parse::<usize>() {
            Ok(n) if n > 0 => cfg.inflight_cap = n,
            _ => {
                eprintln!("serve --route: --inflight-cap expects a positive count, got {s:?}");
                return 2;
            }
        }
    }
    if let Some(s) = args.get("slow-threshold-us") {
        match s.parse::<u64>() {
            Ok(us) => cfg.slow_threshold_us = us,
            Err(_) => {
                eprintln!(
                    "serve --route: --slow-threshold-us expects a microsecond count, got {s:?}"
                );
                return 2;
            }
        }
    }
    if let Some(s) = args.get("trace-ring") {
        match s.parse::<usize>() {
            Ok(n) => cfg.trace_ring = n,
            Err(_) => {
                eprintln!("serve --route: --trace-ring expects a capacity, got {s:?}");
                return 2;
            }
        }
    }
    let probe_ms = cfg.health.probe_interval.as_millis();
    let (replication, hot_k, max_tries) = (cfg.replication, cfg.hot_k, cfg.max_tries);
    let router = match Router::start(&addrs, port, cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("router: {e:#}");
            return 1;
        }
    };
    println!(
        "routing across {} backends on {} (replication {} for top-{} hot keys, \
         {} tries, probes every {} ms)",
        addrs.len(),
        router.addr(),
        replication,
        hot_k,
        max_tries,
        probe_ms
    );
    println!(
        "protocol: PREDICT | PIPE <id> PREDICT ... | LIST | STATS | METRICS | SLOW | QUIT \
         (routed; see rust/PROTOCOL.md § Routing)"
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// `repro loadgen`: the seed-replayable adversarial workload harness.
/// Generates a deterministic multi-tenant trace (Zipf popularity, Poisson
/// arrivals, one of five scenario shapes) and either renders it
/// (`--trace-only`), replays it against a live server (`--addr`), or
/// self-hosts a budgeted spill-tier store and measures hot-set hit rates —
/// optionally under both admission policies (`--compare-admission`) —
/// writing per-scenario latency percentiles to `BENCH_loadgen.json`.
fn cmd_loadgen(args: &Args) -> i32 {
    use rf_compress::coordinator::admission::AdmissionPolicy;
    use rf_compress::testing::loadgen::{
        generate_trace, render_trace, run_trace, LoadgenConfig, RunOptions, Scenario,
    };

    let spec = args.get("scenario").unwrap_or("steady").to_string();
    let mut scenarios = Vec::new();
    if spec == "all" {
        scenarios.extend(Scenario::ALL);
    } else {
        for s in spec.split(',') {
            match Scenario::parse(s) {
                Some(sc) => scenarios.push(sc),
                None => {
                    eprintln!(
                        "loadgen: unknown scenario {s:?} (want steady, diurnal, \
                         flash_crowd, scan, cohort_burst, or all)"
                    );
                    return 2;
                }
            }
        }
    }
    let quick = args.flag("quick");
    let cfg_for = |sc: Scenario| -> LoadgenConfig {
        let base = if quick { LoadgenConfig::quick(sc) } else { LoadgenConfig::new(sc) };
        LoadgenConfig {
            seed: args.get_or("seed", base.seed),
            tenants: args.get_or("tenants", base.tenants),
            requests: args.get_or("requests", base.requests),
            rate: args.get_or("rate", base.rate),
            zipf_s: args.get_or("zipf-s", base.zipf_s),
            hot_set: args.get_or("hot-set", base.hot_set),
            cohort: args.get_or("cohort", base.cohort),
            ..base
        }
    };

    // trace-only: render the deterministic trace and exit — the replay
    // artifact CI byte-compares across two invocations
    if args.flag("trace-only") {
        let mut text = String::new();
        for sc in &scenarios {
            let cfg = cfg_for(*sc);
            text.push_str(&render_trace(&cfg, &generate_trace(&cfg)));
        }
        return match args.get("trace-out") {
            Some(path) => match std::fs::write(path, &text) {
                Ok(()) => {
                    println!("wrote {path}");
                    0
                }
                Err(e) => {
                    eprintln!("loadgen: write {path}: {e}");
                    1
                }
            },
            None => {
                print!("{text}");
                0
            }
        };
    }

    let opts_base = RunOptions {
        pipe: !args.flag("serial"),
        window: args.get_or("window", 128usize),
        ..RunOptions::default()
    };
    let out = args.get("out").unwrap_or("BENCH_loadgen.json").to_string();
    let compare = args.flag("compare-admission");
    let mut entries: Vec<String> = Vec::new();
    let mut gate_ok = true;

    if let Some(addr) = args.get("addr") {
        // external mode: replay against an already-running server
        let addr: std::net::SocketAddr = match addr.parse() {
            Ok(a) => a,
            Err(_) => {
                eprintln!("loadgen: bad --addr {addr:?} (want HOST:PORT)");
                return 2;
            }
        };
        if compare {
            eprintln!("loadgen: --compare-admission needs a self-hosted store (drop --addr)");
            return 2;
        }
        let Some(values) = args.get("values") else {
            eprintln!(
                "loadgen: --addr mode needs --values V1,V2,... (a PREDICT payload \
                 the serving models accept)"
            );
            return 2;
        };
        let models = match args.get_list::<String>("models") {
            Some(m) if !m.is_empty() => m,
            _ => {
                eprintln!(
                    "loadgen: --addr mode needs --models NAME[,NAME...] \
                     (tenant t maps to models[t % len])"
                );
                return 2;
            }
        };
        let opts = RunOptions { values: values.to_string(), ..opts_base };
        for sc in &scenarios {
            let cfg = cfg_for(*sc);
            let trace = generate_trace(&cfg);
            match run_trace(addr, &models, &trace, &opts) {
                Ok(report) => {
                    print_loadgen_line(sc.name(), "external", &report, None);
                    entries.push(loadgen_entry_json(&cfg, "external", &report, None));
                }
                Err(e) => {
                    eprintln!("loadgen {}: {e:#}", sc.name());
                    return 1;
                }
            }
        }
    } else {
        // self-serve mode: train one small forest, host it under every
        // tenant name in a budgeted spill-tier store, and measure
        let policies: Vec<AdmissionPolicy> = if compare {
            vec![AdmissionPolicy::Lru, AdmissionPolicy::TinyLfu]
        } else {
            let p = args.get("admission").unwrap_or("lru");
            match AdmissionPolicy::parse(p) {
                Some(p) => vec![p],
                None => {
                    eprintln!("loadgen: --admission expects lru or tinylfu, got {p:?}");
                    return 2;
                }
            }
        };
        let key = args.get("dataset").unwrap_or("iris");
        let Some(ds) = dataset_by_key(key, args.get_or("data-seed", 1234u64)) else {
            eprintln!("loadgen: unknown dataset {key:?} (try `repro datasets`)");
            return 2;
        };
        let trees = args.get_or("trees", 5usize);
        let mut coord = coordinator(args);
        let cf = match coord.train_and_compress(&ds, trees, args.get_or("seed", 7u64), &opts_from(args))
        {
            Ok((_, cf, _)) => cf,
            Err(e) => {
                eprintln!("loadgen: training the tenant model failed: {e:#}");
                return 1;
            }
        };
        for sc in &scenarios {
            let cfg = cfg_for(*sc);
            let trace = generate_trace(&cfg);
            let mut rates: Vec<(AdmissionPolicy, f64)> = Vec::new();
            for policy in &policies {
                match loadgen_self_run(args, &cfg, &trace, *policy, &cf, &ds, &opts_base) {
                    Ok((report, m)) => {
                        print_loadgen_line(
                            cfg.scenario.name(),
                            &policy.to_string(),
                            &report,
                            Some(&m),
                        );
                        entries.push(loadgen_entry_json(
                            &cfg,
                            &policy.to_string(),
                            &report,
                            Some(&m),
                        ));
                        rates.push((*policy, m.hot_hit_rate));
                    }
                    Err(e) => {
                        eprintln!("loadgen {} [{policy}]: {e:#}", cfg.scenario.name());
                        return 1;
                    }
                }
            }
            if compare {
                // the scan-resistance gate: frequency-weighted admission
                // must retain at least the hot-set hit rate recency alone
                // manages (small epsilon absorbs run-to-run load races)
                let rate_of = |p: AdmissionPolicy| {
                    rates.iter().find(|(q, _)| *q == p).map(|(_, r)| *r).unwrap_or(0.0)
                };
                let (lru, tiny) = (rate_of(AdmissionPolicy::Lru), rate_of(AdmissionPolicy::TinyLfu));
                let ok = tiny + 0.02 >= lru;
                println!(
                    "gate {}: tinylfu hot-hit {:.1}% vs lru {:.1}% => {}",
                    cfg.scenario.name(),
                    tiny * 100.0,
                    lru * 100.0,
                    if ok { "PASS" } else { "FAIL" }
                );
                gate_ok &= ok;
            }
        }
    }

    let json = format!(
        "{{\n  \"bench\": \"loadgen\",\n  \"quick\": {quick},\n  \
         \"compare_admission\": {compare},\n  \"gate\": {{\"pass\": {gate_ok}}},\n  \
         \"entries\": [\n    {}\n  ]\n}}\n",
        entries.join(",\n    ")
    );
    if let Err(e) = std::fs::write(&out, json) {
        eprintln!("loadgen: write {out}: {e}");
        return 1;
    }
    println!("wrote {out}");
    if gate_ok {
        0
    } else {
        1
    }
}

/// Store-side measurements of one self-served loadgen run (all counter
/// deltas across the measurement window).
struct LoadgenMeasure {
    hot_requests: u64,
    cold_requests: u64,
    promotions: u64,
    admission_rejects: u64,
    hot_hit_rate: f64,
}

/// Host a fresh budgeted store for one (scenario, policy) run and execute
/// the trace against it, returning the latency report and the hot-set
/// retention measured from the store's own counters (not timing).
fn loadgen_self_run(
    args: &Args,
    cfg: &rf_compress::testing::loadgen::LoadgenConfig,
    trace: &[rf_compress::testing::loadgen::Request],
    policy: rf_compress::coordinator::admission::AdmissionPolicy,
    cf: &CompressedForest,
    ds: &Dataset,
    opts_base: &rf_compress::testing::loadgen::RunOptions,
) -> anyhow::Result<(rf_compress::testing::loadgen::RunReport, LoadgenMeasure)> {
    use rf_compress::coordinator::server::{values_to_wire, Client};
    use rf_compress::coordinator::store::ObsValue;
    use rf_compress::data::Column;
    use rf_compress::testing::loadgen::{hot_hit_rate, hot_tenants, run_trace, RunOptions};

    let one = cf.total_bytes();
    // default budget: the hot set fits with a little slack, the long tail
    // does not — exactly the regime admission policy decides
    let budget = match args.get("max-resident-bytes") {
        Some(s) => s.parse::<u64>().map_err(|_| {
            anyhow::anyhow!("--max-resident-bytes expects a byte count, got {s:?}")
        })?,
        None => one * (cfg.hot_set as u64 + 2),
    };
    let (dir, cleanup) = match args.get("spill-dir") {
        Some(d) => (std::path::PathBuf::from(d), false),
        None => (
            std::env::temp_dir()
                .join(format!("rfc-loadgen-{policy}-{}", std::process::id())),
            true,
        ),
    };
    let store = Arc::new(
        ModelStore::with_config(rf_compress::coordinator::store::DEFAULT_SHARDS, Some(budget))
            .admission(policy)
            .spill_dir(dir.clone()),
    );
    for t in 0..cfg.tenants {
        store.insert(&format!("t{t}"), cf)?;
    }
    let server = Server::start_with(store.clone(), 0, ServerConfig::default())?;
    let addr = server.addr();
    let values = values_to_wire(
        &ds.features
            .iter()
            .map(|f| match &f.column {
                Column::Numeric(v) => ObsValue::Num(v[0]),
                Column::Categorical { values, .. } => ObsValue::Cat(values[0]),
            })
            .collect::<Vec<_>>(),
    );

    // warm the hot set before the measurement window: "hot" means resident
    // and (under tinylfu) frequency-known
    let hot = hot_tenants(cfg);
    let mut client = Client::connect(addr)?;
    for _ in 0..3 {
        for t in &hot {
            client.request(&format!("PREDICT t{t} {values}"))?;
        }
    }
    let before = store.stats();

    let opts = RunOptions { values, ..opts_base.clone() };
    let report = run_trace(addr, &loadgen_model_names(cfg.tenants), trace, &opts)?;

    let after = store.stats();
    let promotions =
        (after.reloads - before.reloads) + (after.pack_loads - before.pack_loads);
    let (hot_requests, cold_requests) =
        rf_compress::testing::loadgen::split_hot_cold(trace, &hot);
    let m = LoadgenMeasure {
        hot_requests,
        cold_requests,
        promotions,
        admission_rejects: after.admission_rejects - before.admission_rejects,
        hot_hit_rate: hot_hit_rate(hot_requests, cold_requests, promotions),
    };
    let _ = client.send("QUIT");
    if cleanup {
        let _ = std::fs::remove_dir_all(&dir);
    }
    Ok((report, m))
}

/// Tenant index → model name mapping the self-serve harness inserts under.
fn loadgen_model_names(tenants: usize) -> Vec<String> {
    (0..tenants).map(|t| format!("t{t}")).collect()
}

fn print_loadgen_line(
    scenario: &str,
    policy: &str,
    r: &rf_compress::testing::loadgen::RunReport,
    m: Option<&LoadgenMeasure>,
) {
    println!(
        "{scenario} [{policy}]: {}/{} ok ({} err), p50 {} µs p95 {} p99 {} max {} \
         in {:.2}s{}",
        r.ok,
        r.sent,
        r.errors,
        r.p50_us,
        r.p95_us,
        r.p99_us,
        r.max_us,
        r.elapsed_s,
        match m {
            Some(m) => format!(
                ", hot-hit {:.1}% ({} rejects)",
                m.hot_hit_rate * 100.0,
                m.admission_rejects
            ),
            None => String::new(),
        }
    );
}

fn loadgen_entry_json(
    cfg: &rf_compress::testing::loadgen::LoadgenConfig,
    policy: &str,
    r: &rf_compress::testing::loadgen::RunReport,
    m: Option<&LoadgenMeasure>,
) -> String {
    let mut s = format!(
        "{{\"scenario\": \"{}\", \"policy\": \"{policy}\", \"seed\": {}, \
         \"tenants\": {}, \"requests\": {}, \"sent\": {}, \"ok\": {}, \
         \"errors\": {}, \"p50_us\": {}, \"p95_us\": {}, \"p99_us\": {}, \
         \"max_us\": {}, \"elapsed_s\": {:.3}",
        cfg.scenario.name(),
        cfg.seed,
        cfg.tenants,
        cfg.requests,
        r.sent,
        r.ok,
        r.errors,
        r.p50_us,
        r.p95_us,
        r.p99_us,
        r.max_us,
        r.elapsed_s
    );
    if let Some(m) = m {
        s.push_str(&format!(
            ", \"hot_requests\": {}, \"cold_requests\": {}, \"promotions\": {}, \
             \"admission_rejects\": {}, \"hot_hit_rate\": {:.4}",
            m.hot_requests, m.cold_requests, m.promotions, m.admission_rejects, m.hot_hit_rate
        ));
    }
    s.push('}');
    s
}

/// RFPK model packs: `pack build` (from container files, or a synthetic
/// per-user cohort trained on a dataset key), `pack list`, `pack extract`.
fn cmd_pack(args: &Args) -> i32 {
    use rf_compress::pack::{PackArchive, PackBuilder};
    match args.positional(1).unwrap_or("") {
        "build" => {
            let Some(out) = args.get("out") else {
                eprintln!("pack build needs --out FILE");
                return 2;
            };
            let mut builder = PackBuilder::new().shared(!args.flag("no-shared"));
            if let Some(inputs) = args.get_list::<String>("inputs") {
                // container-file mode: keys are the file stems
                for path in &inputs {
                    let p = std::path::Path::new(path);
                    let key = p
                        .file_stem()
                        .and_then(|s| s.to_str())
                        .unwrap_or("model")
                        .to_string();
                    let bytes = match std::fs::read(p) {
                        Ok(b) => b,
                        Err(e) => {
                            eprintln!("read {path}: {e}");
                            return 1;
                        }
                    };
                    if let Err(e) = builder.add(&key, bytes) {
                        eprintln!("pack build: {e:#}");
                        return 1;
                    }
                }
            } else if args.get("dataset").is_some() {
                // synthetic cohort mode: N tiny per-user forests on one
                // dataset, compressed against shared cohort codebooks
                let Some(ds) = load_dataset(args) else { return 2 };
                let members = args.get_or("members", 16usize);
                let trees = args.get_or("trees", 2usize);
                let seed = args.get_or("seed", 7u64);
                let params = if ds.target.is_classification() {
                    rf_compress::forest::ForestParams::classification(trees)
                } else {
                    rf_compress::forest::ForestParams::regression(trees)
                };
                let forests: Vec<rf_compress::forest::Forest> = (0..members)
                    .map(|i| {
                        rf_compress::forest::Forest::train(&ds, &params, seed + i as u64)
                    })
                    .collect();
                let cohort = match rf_compress::pack::compress_cohort(
                    &forests,
                    &ds,
                    &opts_from(args),
                ) {
                    Ok(c) => c,
                    Err(e) => {
                        eprintln!("pack build: {e:#}");
                        return 1;
                    }
                };
                let width = members.to_string().len().max(4);
                for (i, cf) in cohort.iter().enumerate() {
                    let key = format!("user-{i:0width$}");
                    if let Err(e) = builder.add(&key, cf.bytes.clone()) {
                        eprintln!("pack build: {e:#}");
                        return 1;
                    }
                }
            } else {
                eprintln!("pack build needs --inputs FILES or --dataset KEY --members N");
                return 2;
            }
            match builder.write(std::path::Path::new(out)) {
                Ok(stats) => {
                    println!(
                        "wrote {out}: {} members, {} ({} logical, {} saved by {} shared \
                         blob(s), {:.1} bytes/member)",
                        stats.members,
                        human_bytes(stats.archive_bytes),
                        human_bytes(stats.logical_bytes),
                        human_bytes(stats.shared_saved_bytes),
                        stats.blobs,
                        stats.archive_bytes as f64 / stats.members.max(1) as f64
                    );
                    0
                }
                Err(e) => {
                    eprintln!("pack build: {e:#}");
                    1
                }
            }
        }
        "init" => {
            let Some(dir) = args.get("chain") else {
                eprintln!("pack init needs --chain DIR");
                return 2;
            };
            match rf_compress::pack::PackChain::create(std::path::Path::new(dir)) {
                Ok(_) => {
                    println!("initialized empty chain at {dir}");
                    0
                }
                Err(e) => {
                    eprintln!("pack init: {e:#}");
                    1
                }
            }
        }
        "append" => {
            let Some(dir) = args.get("chain") else {
                eprintln!("pack append needs --chain DIR");
                return 2;
            };
            let Some(members) = chain_members_from_args(args) else { return 2 };
            let mut chain = match rf_compress::pack::PackChain::open(std::path::Path::new(dir))
            {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("pack append: {e:#}");
                    return 1;
                }
            };
            match chain.append_members(&members) {
                Ok(seq) => {
                    println!(
                        "appended {} member(s) as generation {seq} ({} generations, \
                         {} live)",
                        members.len(),
                        chain.generation_count(),
                        chain.live_len()
                    );
                    0
                }
                Err(e) => {
                    eprintln!("pack append: {e:#}");
                    1
                }
            }
        }
        "remove" => {
            let Some(dir) = args.get("chain") else {
                eprintln!("pack remove needs --chain DIR");
                return 2;
            };
            let Some(keys) = args.get_list::<String>("keys") else {
                eprintln!("pack remove needs --keys K[,K...]");
                return 2;
            };
            let mut chain = match rf_compress::pack::PackChain::open(std::path::Path::new(dir))
            {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("pack remove: {e:#}");
                    return 1;
                }
            };
            match chain.remove_members(&keys) {
                Ok(seq) => {
                    println!(
                        "tombstoned {} key(s) as generation {seq} ({} generations, \
                         {} live, {} tombstones)",
                        keys.len(),
                        chain.generation_count(),
                        chain.live_len(),
                        chain.tombstone_count()
                    );
                    0
                }
                Err(e) => {
                    eprintln!("pack remove: {e:#}");
                    1
                }
            }
        }
        "compact" => {
            let Some(dir) = args.get("chain") else {
                eprintln!("pack compact needs --chain DIR");
                return 2;
            };
            let mut chain = match rf_compress::pack::PackChain::open(std::path::Path::new(dir))
            {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("pack compact: {e:#}");
                    return 1;
                }
            };
            // default: byte-level merge (bit-identical members). With
            // --dataset: decode and re-run cohort clustering so members
            // appended in separate delta cohorts re-share codebooks.
            let result = if args.get("dataset").is_some() {
                let Some(ds) = load_dataset(args) else { return 2 };
                let opts = opts_from(args);
                rf_compress::pack::compact_chain(
                    &mut chain,
                    rf_compress::pack::CompactMode::Recluster { ds: &ds, opts: &opts },
                )
            } else {
                rf_compress::pack::compact_chain(&mut chain, rf_compress::pack::CompactMode::Merge)
            };
            match result {
                Ok(s) if s.generations_before <= 1 && s.tombstones_cleared == 0 => {
                    println!("chain {dir} is already compact ({} live member(s))", s.live_members);
                    0
                }
                Ok(s) => {
                    println!(
                        "compacted {dir}: {} generation(s) → 1 (gen {}), {} live, \
                         {} tombstone(s) cleared, {} → {}",
                        s.generations_before,
                        s.new_seq,
                        s.live_members,
                        s.tombstones_cleared,
                        human_bytes(s.bytes_before),
                        human_bytes(s.bytes_after)
                    );
                    0
                }
                Err(e) => {
                    eprintln!("pack compact: {e:#}");
                    1
                }
            }
        }
        "list" => {
            if let Some(dir) = args.get("chain") {
                let chain = match rf_compress::pack::PackChain::open(std::path::Path::new(dir))
                {
                    Ok(c) => c,
                    Err(e) => {
                        eprintln!("pack list: {e:#}");
                        return 1;
                    }
                };
                println!("{:<24} {:>12}  generation", "key", "container");
                for key in chain.live_keys() {
                    let (pack, m) = chain.resolve(key).expect("live key resolves");
                    println!(
                        "{:<24} {:>12}  {}",
                        key,
                        human_bytes(pack.member_logical_bytes(m)),
                        chain.resolve_seq(key).unwrap_or(0)
                    );
                }
                let s = chain.stats();
                println!(
                    "chain: {} generation(s), {} live of {} stored, {} tombstone(s), \
                     {} archive bytes",
                    s.generations,
                    s.live_members,
                    s.stored_members,
                    s.tombstones,
                    human_bytes(s.archive_bytes)
                );
                return 0;
            }
            let Some(input) = args.get("in") else {
                eprintln!("pack list needs --in FILE or --chain DIR");
                return 2;
            };
            let pack = match PackArchive::open(std::path::Path::new(input)) {
                Ok(p) => p,
                Err(e) => {
                    eprintln!("pack list: {e:#}");
                    return 1;
                }
            };
            println!("{:<24} {:>12} {:>12}  storage", "key", "stored", "container");
            for i in 0..pack.member_count() {
                println!(
                    "{:<24} {:>12} {:>12}  {}",
                    pack.key(i),
                    human_bytes(pack.member_stored_bytes(i)),
                    human_bytes(pack.member_logical_bytes(i)),
                    if pack.member_is_shared(i) { "shared-dicts" } else { "verbatim" }
                );
            }
            let s = pack.stats();
            println!(
                "total: {} members, {} archive ({} logical; {} saved by {} shared blob(s))",
                s.members,
                human_bytes(s.archive_bytes),
                human_bytes(s.logical_bytes),
                human_bytes(s.shared_saved_bytes),
                s.blobs
            );
            0
        }
        "extract" => {
            let Some(input) = args.get("in") else {
                eprintln!("pack extract needs --in FILE");
                return 2;
            };
            let pack = match PackArchive::open(std::path::Path::new(input)) {
                Ok(p) => p,
                Err(e) => {
                    eprintln!("pack extract: {e:#}");
                    return 1;
                }
            };
            if let Some(key) = args.get("key") {
                let Some(out) = args.get("out") else {
                    eprintln!("pack extract --key needs --out FILE");
                    return 2;
                };
                match pack.extract_by_key(key).and_then(|bytes| {
                    std::fs::write(out, &bytes)?;
                    Ok(bytes.len())
                }) {
                    Ok(n) => {
                        println!("extracted {key} → {out} ({})", human_bytes(n as u64));
                        0
                    }
                    Err(e) => {
                        eprintln!("pack extract: {e:#}");
                        1
                    }
                }
            } else {
                let Some(dir) = args.get("out-dir") else {
                    eprintln!("pack extract needs --key K --out FILE or --out-dir DIR");
                    return 2;
                };
                let dir = std::path::Path::new(dir);
                if let Err(e) = std::fs::create_dir_all(dir) {
                    eprintln!("pack extract: creating {}: {e}", dir.display());
                    return 1;
                }
                for i in 0..pack.member_count() {
                    let path = dir.join(format!("{}.rfcz", pack.key(i)));
                    match pack.extract_member(i).and_then(|bytes| {
                        std::fs::write(&path, &bytes)?;
                        Ok(())
                    }) {
                        Ok(()) => {}
                        Err(e) => {
                            eprintln!("pack extract {}: {e:#}", pack.key(i));
                            return 1;
                        }
                    }
                }
                println!("extracted {} members to {}", pack.member_count(), dir.display());
                0
            }
        }
        other => {
            eprintln!(
                "unknown pack subcommand {other:?} \
                 (build | list | extract | init | append | remove | compact)"
            );
            2
        }
    }
}

/// Collect the members a `pack append` adds, in either input mode:
/// `--inputs A.rfcz[,...]` (keys are the file stems) or `--dataset KEY
/// --members N` (a fresh cohort, compressed against its own shared
/// codebooks; `--key-offset` shifts the `user-NNNN` numbering so appended
/// cohorts don't collide with the base's keys — same-keyed members
/// *replace* rather than add). Prints the usage error and returns `None`
/// on misuse.
fn chain_members_from_args(args: &Args) -> Option<Vec<(String, std::sync::Arc<[u8]>)>> {
    if let Some(inputs) = args.get_list::<String>("inputs") {
        let mut members = Vec::new();
        for path in &inputs {
            let p = std::path::Path::new(path);
            let key = p.file_stem().and_then(|s| s.to_str()).unwrap_or("model").to_string();
            match std::fs::read(p) {
                Ok(b) => members.push((key, std::sync::Arc::<[u8]>::from(b))),
                Err(e) => {
                    eprintln!("read {path}: {e}");
                    return None;
                }
            }
        }
        Some(members)
    } else if args.get("dataset").is_some() {
        let ds = load_dataset(args)?;
        let members = args.get_or("members", 4usize);
        let trees = args.get_or("trees", 2usize);
        let seed = args.get_or("seed", 7u64);
        let offset = args.get_or("key-offset", 0usize);
        let params = if ds.target.is_classification() {
            rf_compress::forest::ForestParams::classification(trees)
        } else {
            rf_compress::forest::ForestParams::regression(trees)
        };
        let forests: Vec<rf_compress::forest::Forest> = (0..members)
            .map(|i| rf_compress::forest::Forest::train(&ds, &params, seed + (offset + i) as u64))
            .collect();
        let cohort = match rf_compress::pack::compress_cohort(&forests, &ds, &opts_from(args)) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("pack append: {e:#}");
                return None;
            }
        };
        let width = (offset + members).to_string().len().max(4);
        Some(
            cohort
                .iter()
                .enumerate()
                .map(|(i, cf)| (format!("user-{:0width$}", offset + i), cf.bytes.clone()))
                .collect(),
        )
    } else {
        eprintln!("pack append needs --inputs FILES or --dataset KEY --members N");
        None
    }
}

/// Per-dataset stage-chain ablation (`repro sweep-stages`): compress the
/// same forest under candidate per-section chains, verify every round trip
/// (bit-exact for lossless chains; within the §7 convert bound for lossy
/// fit chains), time decode, and write the machine-readable
/// `BENCH_stages.json`. Doubles as the CI gate: the chainless encoding must
/// stay byte-identical to the fixed four-stage pipeline (the differential
/// oracle) and its decode throughput within `--tolerance` across runs.
fn cmd_sweep_stages(args: &Args) -> i32 {
    use rf_compress::coding::stage::{parse_chain, SectionChains};
    use rf_compress::forest::Fit;
    use rf_compress::lossy::theory::chain_mse_bound;
    use rf_compress::util::bench::{time_it, Table};

    let Some(ds) = load_dataset(args) else { return 2 };
    let quick = args.flag("quick");
    let trees = args.get_or("trees", if quick { 8usize } else { 50 });
    let seed = args.get_or("seed", 7u64);
    let tolerance: f64 = args.get_or("tolerance", 0.4f64);
    let budget = if quick { 0.05 } else { 0.4 };
    let out = args.get("out").unwrap_or("BENCH_stages.json").to_string();
    let regression = !ds.target.is_classification();
    let dataset_key = args.get("dataset").unwrap_or("csv").to_string();

    let coord = coordinator(args);
    let forest = coord.train(&ds, trees, seed);
    let nodes = forest.total_nodes() as f64;
    let base_opts = CompressOptions { chains: SectionChains::default(), ..opts_from(args) };

    // the fixed-pipeline baseline: a chainless version-1 container
    let baseline = match CompressedForest::compress(&forest, &ds, &base_opts) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("sweep-stages: baseline compression failed: {e:#}");
            return 1;
        }
    };
    let base_bytes = baseline.total_bytes();
    let base_t = time_it(budget, 3, || {
        std::hint::black_box(baseline.decompress().unwrap());
    });
    let base_per_s = base_t.per_sec(nodes);
    println!(
        "baseline (no chains): {} trees, {}, decode {:.0} nodes/s",
        forest.num_trees(),
        human_bytes(base_bytes),
        base_per_s
    );

    // candidate chains per section, swept one section at a time (ablation):
    // lossy converts are only legal on regression fit tables
    let struct_cands: &[&str] =
        if quick { &["lzss"] } else { &["lzss", "huff", "xor+lzss"] };
    let split_cands: &[&str] =
        if quick { &["delta+lzss"] } else { &["delta+lzss", "xor+huff", "split8+lzss"] };
    let fit_cands: &[&str] = match (regression, quick) {
        (true, true) => &["bf16+lzss"],
        (true, false) => &["delta+lzss", "split8+huff", "f32+lzss", "bf16+lzss"],
        (false, true) => &["delta+lzss"],
        (false, false) => &["delta+lzss", "split8+huff"],
    };
    let mut cases: Vec<(&str, String, SectionChains)> = Vec::new();
    for c in struct_cands {
        let structure = parse_chain(c).expect("candidate chain parses");
        cases.push(("struct", c.to_string(), SectionChains { structure, ..Default::default() }));
    }
    for c in split_cands {
        let split_tables = parse_chain(c).expect("candidate chain parses");
        cases.push(("split", c.to_string(), SectionChains { split_tables, ..Default::default() }));
    }
    for c in fit_cands {
        let fit_table = parse_chain(c).expect("candidate chain parses");
        cases.push(("fit", c.to_string(), SectionChains { fit_table, ..Default::default() }));
    }

    let fits_of = |fo: &rf_compress::forest::Forest| -> Vec<f64> {
        fo.trees
            .iter()
            .flat_map(|t| t.nodes.iter())
            .map(|n| match n.fit {
                Fit::Regression(v) => v,
                Fit::Class(c) => c as f64,
            })
            .collect()
    };

    let mut table = Table::new(&["section", "chain", "bytes", "vs base", "nodes/s", "kind"]);
    let mut entries: Vec<String> = Vec::new();
    let mut failures = 0usize;
    for (section, label, chains) in &cases {
        let lossy_chain = chains.is_lossy();
        let opts = CompressOptions { chains: chains.clone(), ..base_opts.clone() };
        let cf = match CompressedForest::compress(&forest, &ds, &opts) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("sweep-stages {section} {label}: {e:#}");
                failures += 1;
                continue;
            }
        };
        let verified = match cf.decompress() {
            Err(e) => {
                eprintln!("sweep-stages {section} {label}: decode failed: {e:#}");
                false
            }
            Ok(g) if lossy_chain => {
                // a lossy fit chain rounds the fit table; everything else —
                // structure, splits, node counts — stays exact, and every
                // fit lands within the §7 convert-stage MSE bound
                let (orig, dec) = (fits_of(&forest), fits_of(&g));
                let vmax = orig.iter().fold(0.0f64, |m, v| m.max(v.abs()));
                let bound = chain_mse_bound(&chains.fit_table, vmax).unwrap_or(0.0);
                g.total_nodes() == forest.total_nodes()
                    && orig.len() == dec.len()
                    && orig.iter().zip(&dec).all(|(a, b)| (a - b) * (a - b) <= bound)
            }
            Ok(g) => g.identical(&forest),
        };
        if !verified {
            eprintln!("sweep-stages {section} {label}: VERIFICATION FAILED");
            failures += 1;
        }
        let t = time_it(budget, 3, || {
            std::hint::black_box(cf.decompress().unwrap());
        });
        let per_s = t.per_sec(nodes);
        table.row(&[
            section.to_string(),
            label.clone(),
            cf.total_bytes().to_string(),
            format!("{:+.1}%", (cf.total_bytes() as f64 / base_bytes as f64 - 1.0) * 100.0),
            format!("{per_s:.0}"),
            if lossy_chain { "lossy".into() } else { "lossless".into() },
        ]);
        entries.push(format!(
            "{{\"section\": \"{section}\", \"chain\": \"{label}\", \"bytes\": {}, \
             \"decode_nodes_per_s\": {per_s:.1}, \"lossy\": {lossy_chain}, \
             \"verified\": {verified}}}",
            cf.total_bytes()
        ));
    }
    table.print();

    // gate 1 (differential oracle): re-encoding with explicitly-empty chains
    // must reproduce the fixed pipeline byte for byte, as a v1 container
    let empty = CompressedForest::compress(&forest, &ds, &base_opts).unwrap();
    let oracle_ok = empty.bytes == baseline.bytes
        && baseline.bytes[4] == rf_compress::compress::container::VERSION;
    // gate 2: chainless decode throughput is stable within --tolerance
    let recheck = time_it(budget, 3, || {
        std::hint::black_box(baseline.decompress().unwrap());
    });
    let decode_ok = recheck.per_sec(nodes) >= base_per_s * (1.0 - tolerance);
    let pass = oracle_ok && decode_ok && failures == 0;
    println!(
        "gate: oracle {} | decode {} | chain failures {} => {}",
        if oracle_ok { "byte-identical" } else { "MISMATCH" },
        if decode_ok { "within tolerance" } else { "REGRESSED" },
        failures,
        if pass { "PASS" } else { "FAIL" }
    );

    let json = format!(
        "{{\n  \"bench\": \"stages\",\n  \"dataset\": \"{dataset_key}\",\n  \
         \"trees\": {trees},\n  \"quick\": {quick},\n  \"tolerance\": {tolerance},\n  \
         \"baseline\": {{\"bytes\": {base_bytes}, \"decode_nodes_per_s\": \
         {base_per_s:.1}, \"version\": {}}},\n  \"entries\": [\n    {}\n  ],\n  \
         \"gate\": {{\"oracle_bytes_identical\": {oracle_ok}, \
         \"decode_within_tolerance\": {decode_ok}, \"chain_failures\": {failures}, \
         \"pass\": {pass}}}\n}}\n",
        baseline.bytes[4],
        entries.join(",\n    ")
    );
    if let Err(e) = std::fs::write(&out, json) {
        eprintln!("sweep-stages: write {out}: {e}");
        return 1;
    }
    println!("wrote {out}");
    if pass {
        0
    } else {
        1
    }
}

/// CI bench-regression gate: compare a fresh `BENCH_serve.json` against the
/// committed `BENCH_baseline.json` (exit 1 on regression past ±tolerance).
/// With `--write-baseline`, rewrite the baseline from the current report
/// instead (validating the gated metrics first).
fn cmd_bench_gate(args: &Args) -> i32 {
    if args.flag("write-baseline") {
        let Some(current) = args.get("current") else {
            eprintln!("bench-gate --write-baseline needs --current FILE");
            return 2;
        };
        let baseline = args.get("baseline").unwrap_or("BENCH_baseline.json");
        return match rf_compress::util::benchgate::write_baseline(
            std::path::Path::new(current),
            std::path::Path::new(baseline),
        ) {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("bench-gate: {e:#}");
                2
            }
        };
    }
    let Some(baseline) = args.get("baseline") else {
        eprintln!("bench-gate needs --baseline FILE");
        return 2;
    };
    let Some(current) = args.get("current") else {
        eprintln!("bench-gate needs --current FILE");
        return 2;
    };
    let tolerance: f64 = args.get_or("tolerance", 0.25f64);
    if !(0.0..1.0).contains(&tolerance) {
        eprintln!("bench-gate: --tolerance must be in [0, 1), got {tolerance}");
        return 2;
    }
    match rf_compress::util::benchgate::run_files(
        std::path::Path::new(baseline),
        std::path::Path::new(current),
        tolerance,
    ) {
        Ok(true) => 0,
        Ok(false) => 1,
        Err(e) => {
            eprintln!("bench-gate: {e:#}");
            2
        }
    }
}

fn cmd_suite(args: &Args) -> i32 {
    let paper_scale = args.flag("paper-scale");
    let trees = args.get_or("trees", if paper_scale { 1000 } else { 25 });
    let mut coord = coordinator(args);
    println!("engine: {}; {} trees per forest", coord.engine_name(), trees);
    println!(
        "{:<22} {:>12} {:>12} {:>12}   ratios",
        "dataset", "standard", "light", "ours"
    );
    for entry in table2_suite() {
        let ds = (entry.make)(1234);
        match coord.train_and_compress(&ds, trees, 7, &opts_from(args)) {
            Ok((_, _, report)) => println!("{}", report.table_row()),
            Err(e) => eprintln!("{}: {e:#}", entry.key),
        }
    }
    0
}

fn print_report(r: &rf_compress::coordinator::CompressionReport) {
    println!(
        "{}: {} trees, {} nodes, mean depth {:.1}",
        r.dataset, r.n_trees, r.total_nodes, r.mean_depth
    );
    println!(
        "  standard {:>12}   light {:>12}   ours {:>12}",
        human_bytes(r.standard_bytes),
        human_bytes(r.light_bytes),
        human_bytes(r.ours_bytes)
    );
    let c = r.sections.paper_columns();
    println!(
        "  breakdown: struct {} | vars {} | splits {} | fits {} | dict {}",
        human_bytes(c.structure),
        human_bytes(c.var_names),
        human_bytes(c.split_values),
        human_bytes(c.fits),
        human_bytes(c.dict)
    );
    println!(
        "  ratios: 1:{:.1} vs standard, 1:{:.1} vs light; clusters: {:?}",
        r.standard_ratio(),
        r.light_ratio(),
        r.cluster_ks.iter().map(|(_, k)| *k).collect::<Vec<_>>()
    );
    println!(
        "  times: train {:.2}s, compress {:.2}s (engine {}, {} xla / {} native steps)",
        r.train_s, r.compress_s, r.engine, r.xla_steps, r.native_steps
    );
}

#[cfg(test)]
mod tests {
    /// Drift guard for the operator guide: every CLI flag the built-in help
    /// documents for `serve` and `loadgen` must appear backticked in
    /// `rust/OPERATIONS.md`, and the guide must name every `BENCH_*.json`
    /// artifact the tooling writes. Adding a flag without documenting it
    /// fails here, not in a code review.
    #[test]
    fn operations_guide_covers_every_serve_and_loadgen_flag() {
        let ops = include_str!("../OPERATIONS.md");
        let mut current = String::new();
        let mut missing: Vec<String> = Vec::new();
        for line in super::HELP.lines() {
            let trimmed = line.trim_start();
            // command lines sit at exactly two spaces of indent; deeper
            // lines continue the current command's flag list
            if line.len() - trimmed.len() == 2 {
                current = trimmed.split_whitespace().next().unwrap_or("").to_string();
            }
            if current != "serve" && current != "loadgen" {
                continue;
            }
            for tok in trimmed.split_whitespace() {
                let tok = tok.trim_matches(|c| matches!(c, '[' | ']' | '(' | ')'));
                if tok.starts_with("--") && !ops.contains(&format!("`{tok}`")) {
                    missing.push(format!("{current}: {tok}"));
                }
            }
        }
        assert!(
            missing.is_empty(),
            "rust/OPERATIONS.md does not document: {missing:?}"
        );
        for bench in [
            "BENCH_serve.json",
            "BENCH_spill.json",
            "BENCH_pack.json",
            "BENCH_stages.json",
            "BENCH_route.json",
            "BENCH_loadgen.json",
            "BENCH_obs.json",
        ] {
            assert!(ops.contains(bench), "rust/OPERATIONS.md must explain {bench}");
        }
    }

    /// The help text itself names every loadgen scenario (the glossary the
    /// guide and protocol doc key off).
    #[test]
    fn help_names_every_loadgen_scenario() {
        for sc in rf_compress::testing::loadgen::Scenario::ALL {
            assert!(
                super::HELP.contains(sc.name()),
                "HELP must mention scenario {:?}",
                sc.name()
            );
        }
    }
}
