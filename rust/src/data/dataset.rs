//! Dataset container: a column-oriented table with numerical and categorical
//! features and a regression or classification target.
//!
//! The paper's tree compressor cares about exactly the attributes CART sees:
//! feature kind (numerical splits carry an *ordered, continuous* value;
//! categorical splits are a *set partition* of category levels, §3.2.2), so
//! the container keeps that distinction first-class.

use anyhow::{bail, Result};

/// One feature column.
#[derive(Debug, Clone)]
pub enum Column {
    /// Numerical feature values.
    Numeric(Vec<f64>),
    /// Categorical feature: level index per row + number of levels.
    Categorical {
        /// Level index per row.
        values: Vec<u32>,
        /// Number of distinct levels.
        levels: u32,
    },
}

impl Column {
    /// Number of rows in the column.
    pub fn len(&self) -> usize {
        match self {
            Column::Numeric(v) => v.len(),
            Column::Categorical { values, .. } => values.len(),
        }
    }

    /// Whether the column has no rows.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether the column is numeric (vs categorical).
    pub fn is_numeric(&self) -> bool {
        matches!(self, Column::Numeric(_))
    }
}

/// Feature descriptor (name + column data).
#[derive(Debug, Clone)]
pub struct Feature {
    /// Feature name.
    pub name: String,
    /// The column's values.
    pub column: Column,
}

/// Prediction target.
#[derive(Debug, Clone)]
pub enum Target {
    /// Regression: real-valued response.
    Regression(Vec<f64>),
    /// Classification: class index per row + number of classes.
    Classification {
        /// Class index per row.
        labels: Vec<u32>,
        /// Number of classes.
        classes: u32,
    },
}

impl Target {
    /// Number of rows in the target.
    pub fn len(&self) -> usize {
        match self {
            Target::Regression(v) => v.len(),
            Target::Classification { labels, .. } => labels.len(),
        }
    }

    /// Whether the target is categorical.
    pub fn is_classification(&self) -> bool {
        matches!(self, Target::Classification { .. })
    }

    /// Number of classes (`0` for regression).
    pub fn num_classes(&self) -> u32 {
        match self {
            Target::Regression(_) => 0,
            Target::Classification { classes, .. } => *classes,
        }
    }
}

/// A dataset: named features + target.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Dataset name (reports and error messages).
    pub name: String,
    /// The feature columns.
    pub features: Vec<Feature>,
    /// The prediction target.
    pub target: Target,
}

impl Dataset {
    /// Validate internal consistency.
    pub fn validate(&self) -> Result<()> {
        let n = self.target.len();
        if n == 0 {
            bail!("dataset {}: empty target", self.name);
        }
        for f in &self.features {
            if f.column.len() != n {
                bail!(
                    "dataset {}: feature {} has {} rows, target has {n}",
                    self.name,
                    f.name,
                    f.column.len()
                );
            }
            if let Column::Categorical { values, levels } = &f.column {
                if values.iter().any(|&v| v >= *levels) {
                    bail!("dataset {}: feature {} has out-of-range level", self.name, f.name);
                }
            }
        }
        if let Target::Classification { labels, classes } = &self.target {
            if labels.iter().any(|&l| l >= *classes) {
                bail!("dataset {}: out-of-range class label", self.name);
            }
        }
        Ok(())
    }

    /// Number of observations.
    pub fn num_rows(&self) -> usize {
        self.target.len()
    }

    /// Number of features.
    pub fn num_features(&self) -> usize {
        self.features.len()
    }

    /// Numerical value of feature `j` at row `i` (categorical levels are
    /// exposed as their index; the tree builder branches on column kind).
    pub fn value(&self, row: usize, feature: usize) -> f64 {
        match &self.features[feature].column {
            Column::Numeric(v) => v[row],
            Column::Categorical { values, .. } => values[row] as f64,
        }
    }

    /// Convert a regression dataset to binary classification by thresholding
    /// the response at its mean — the paper's construction for Liberty*,
    /// Airfoil*, Naval* ("classify those homes for which the number of
    /// hazards is greater than the mean", §6).
    pub fn binarize_by_mean(&self) -> Result<Dataset> {
        let y = match &self.target {
            Target::Regression(y) => y,
            Target::Classification { .. } => bail!("already a classification dataset"),
        };
        let mean = y.iter().sum::<f64>() / y.len() as f64;
        let labels: Vec<u32> = y.iter().map(|&v| (v > mean) as u32).collect();
        Ok(Dataset {
            name: format!("{}*", self.name.trim_end_matches('+')),
            features: self.features.clone(),
            target: Target::Classification { labels, classes: 2 },
        })
    }

    /// Select a subset of rows (used by train/test splitting and bootstrap
    /// OOB evaluation).
    pub fn select_rows(&self, rows: &[usize]) -> Dataset {
        let features = self
            .features
            .iter()
            .map(|f| Feature {
                name: f.name.clone(),
                column: match &f.column {
                    Column::Numeric(v) => Column::Numeric(rows.iter().map(|&r| v[r]).collect()),
                    Column::Categorical { values, levels } => Column::Categorical {
                        values: rows.iter().map(|&r| values[r]).collect(),
                        levels: *levels,
                    },
                },
            })
            .collect();
        let target = match &self.target {
            Target::Regression(y) => Target::Regression(rows.iter().map(|&r| y[r]).collect()),
            Target::Classification { labels, classes } => Target::Classification {
                labels: rows.iter().map(|&r| labels[r]).collect(),
                classes: *classes,
            },
        };
        Dataset {
            name: self.name.clone(),
            features,
            target,
        }
    }

    /// Random train/test split (the paper's Figs 2–3 use 80/20).
    pub fn train_test_split(&self, train_frac: f64, rng: &mut crate::util::Pcg64) -> TrainTest {
        let n = self.num_rows();
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let n_train = ((n as f64) * train_frac).round() as usize;
        let (train_idx, test_idx) = idx.split_at(n_train.clamp(1, n - 1));
        TrainTest {
            train: self.select_rows(train_idx),
            test: self.select_rows(test_idx),
        }
    }
}

/// An 80/20-style split.
#[derive(Debug, Clone)]
pub struct TrainTest {
    /// The training split.
    pub train: Dataset,
    /// The held-out test split.
    pub test: Dataset,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn toy() -> Dataset {
        Dataset {
            name: "toy".into(),
            features: vec![
                Feature {
                    name: "x".into(),
                    column: Column::Numeric(vec![1.0, 2.0, 3.0, 4.0]),
                },
                Feature {
                    name: "c".into(),
                    column: Column::Categorical {
                        values: vec![0, 1, 0, 2],
                        levels: 3,
                    },
                },
            ],
            target: Target::Regression(vec![10.0, 20.0, 30.0, 40.0]),
        }
    }

    #[test]
    fn validate_ok_and_accessors() {
        let d = toy();
        d.validate().unwrap();
        assert_eq!(d.num_rows(), 4);
        assert_eq!(d.num_features(), 2);
        assert_eq!(d.value(1, 0), 2.0);
        assert_eq!(d.value(3, 1), 2.0);
    }

    #[test]
    fn validate_catches_row_mismatch() {
        let mut d = toy();
        d.features[0].column = Column::Numeric(vec![1.0]);
        assert!(d.validate().is_err());
    }

    #[test]
    fn validate_catches_bad_level() {
        let mut d = toy();
        d.features[1].column = Column::Categorical {
            values: vec![0, 5, 0, 2],
            levels: 3,
        };
        assert!(d.validate().is_err());
    }

    #[test]
    fn binarize_by_mean_matches_paper_construction() {
        let d = toy(); // mean = 25
        let b = d.binarize_by_mean().unwrap();
        match &b.target {
            Target::Classification { labels, classes } => {
                assert_eq!(*classes, 2);
                assert_eq!(labels, &vec![0, 0, 1, 1]);
            }
            _ => panic!("expected classification"),
        }
        assert!(b.binarize_by_mean().is_err());
    }

    #[test]
    fn select_rows_subsets() {
        let d = toy();
        let s = d.select_rows(&[2, 0]);
        assert_eq!(s.num_rows(), 2);
        assert_eq!(s.value(0, 0), 3.0);
        assert_eq!(s.value(1, 0), 1.0);
    }

    #[test]
    fn split_partitions_rows() {
        let d = toy();
        let mut rng = Pcg64::new(1);
        let tt = d.train_test_split(0.75, &mut rng);
        assert_eq!(tt.train.num_rows() + tt.test.num_rows(), 4);
        assert!(tt.train.num_rows() >= 1 && tt.test.num_rows() >= 1);
    }
}
