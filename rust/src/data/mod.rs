//! Datasets: container types, CSV loading, and synthetic generators that
//! stand in for the paper's UCI/Kaggle datasets (offline substitution —
//! see `DESIGN.md §7`).

pub mod csv;
pub mod dataset;
pub mod synthetic;

pub use dataset::{Column, Dataset, Feature, Target, TrainTest};
