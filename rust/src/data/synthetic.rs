//! Synthetic stand-ins for the paper's evaluation datasets (Table 2).
//!
//! The real UCI/Kaggle files are not available offline, so each generator
//! reproduces the *shape* that matters to the compressor: the same number of
//! observations and variables, the same numeric/categorical mix, and a
//! target driven by a sparse latent rule model so that CART forests trained
//! on it exhibit the statistics the paper exploits — splits concentrated on
//! a few informative features near the root (sparse, low-entropy conditional
//! distributions) and increasingly uniform splits at depth (§6).
//!
//! See `DESIGN.md §7` for the substitution argument.

use super::dataset::{Column, Dataset, Feature, Target};
use crate::util::Pcg64;

/// A latent decision rule: conjunction of feature conditions with a weight.
struct Rule {
    conds: Vec<Cond>,
    weight: f64,
}

enum Cond {
    /// numeric feature > threshold
    Gt(usize, f64),
    /// categorical feature ∈ set (bitmask over levels)
    In(usize, u64),
}

/// Generator configuration; public so ablations can craft custom workloads.
pub struct SynthSpec {
    /// Dataset name.
    pub name: &'static str,
    /// Number of observations.
    pub n_obs: usize,
    /// Number of numeric features.
    pub n_numeric: usize,
    /// Number of categorical features.
    pub n_categorical: usize,
    /// max category levels (levels per feature drawn in 2..=max)
    pub max_levels: u32,
    /// number of latent rules driving the target
    pub n_rules: usize,
    /// fraction of features that are informative (rules only use these)
    pub informative_frac: f64,
    /// classification classes (0 ⇒ regression)
    pub classes: u32,
    /// observation noise scale relative to signal
    pub noise: f64,
}

/// Generate a dataset from a spec. Deterministic in `seed`.
pub fn generate(spec: &SynthSpec, seed: u64) -> Dataset {
    let mut rng = Pcg64::with_stream(seed, 0x5e_ed);
    let d = spec.n_numeric + spec.n_categorical;
    assert!(d > 0 && spec.n_obs > 1);

    // --- feature columns ---
    let mut columns: Vec<Column> = Vec::with_capacity(d);
    let mut level_counts: Vec<u32> = Vec::with_capacity(d);
    for j in 0..d {
        if j < spec.n_numeric {
            // per-feature distribution: uniform, gaussian, or log-scaled
            let kind = rng.gen_index(3);
            let scale = 1.0 + rng.gen_f64() * 9.0;
            let offset = rng.gen_normal() * 2.0;
            let v: Vec<f64> = (0..spec.n_obs)
                .map(|_| match kind {
                    0 => offset + scale * rng.gen_f64(),
                    1 => offset + scale * rng.gen_normal(),
                    _ => offset + scale * (-rng.gen_f64().max(1e-12).ln()),
                })
                .collect();
            columns.push(Column::Numeric(v));
            level_counts.push(0);
        } else {
            let levels = 2 + rng.gen_range((spec.max_levels - 1) as u64) as u32;
            // skewed level popularity (Zipf-ish), like real categoricals
            let weights: Vec<f64> = (0..levels).map(|l| 1.0 / (l + 1) as f64).collect();
            let total: f64 = weights.iter().sum();
            let values: Vec<u32> = (0..spec.n_obs)
                .map(|_| {
                    let mut u = rng.gen_f64() * total;
                    for (l, &w) in weights.iter().enumerate() {
                        if u < w {
                            return l as u32;
                        }
                        u -= w;
                    }
                    levels - 1
                })
                .collect();
            columns.push(Column::Categorical { values, levels });
            level_counts.push(levels);
        }
    }

    // --- latent rules over informative features ---
    let n_inf = ((d as f64) * spec.informative_frac).ceil().max(1.0) as usize;
    let informative = rng.sample_indices(d, n_inf.min(d));
    let mut rules = Vec::with_capacity(spec.n_rules);
    for _ in 0..spec.n_rules {
        let arity = 1 + rng.gen_index(3);
        let mut conds = Vec::with_capacity(arity);
        for _ in 0..arity {
            let f = *rng.choose(&informative);
            match &columns[f] {
                Column::Numeric(v) => {
                    // threshold at a random data quantile → realistic splits
                    let t = v[rng.gen_index(v.len())];
                    conds.push(Cond::Gt(f, t));
                }
                Column::Categorical { levels, .. } => {
                    // random non-trivial subset of levels
                    let mut mask = 0u64;
                    for l in 0..*levels {
                        if rng.gen_bool(0.5) {
                            mask |= 1 << l;
                        }
                    }
                    if mask == 0 || mask == (1u64 << levels) - 1 {
                        mask = 1;
                    }
                    conds.push(Cond::In(f, mask));
                }
            }
        }
        rules.push(Rule {
            conds,
            weight: rng.gen_normal() * 3.0,
        });
    }

    // --- scores ---
    let mut score = vec![0.0f64; spec.n_obs];
    for rule in &rules {
        for (i, s) in score.iter_mut().enumerate() {
            let fire = rule.conds.iter().all(|c| match *c {
                Cond::Gt(f, t) => match &columns[f] {
                    Column::Numeric(v) => v[i] > t,
                    _ => unreachable!(),
                },
                Cond::In(f, mask) => match &columns[f] {
                    Column::Categorical { values, .. } => mask >> values[i] & 1 == 1,
                    _ => unreachable!(),
                },
            });
            if fire {
                *s += rule.weight;
            }
        }
    }
    let sig_std = {
        let mean = score.iter().sum::<f64>() / score.len() as f64;
        (score.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / score.len() as f64)
            .sqrt()
            .max(1e-9)
    };
    for s in score.iter_mut() {
        *s += rng.gen_normal() * spec.noise * sig_std;
    }

    // --- target ---
    let target = if spec.classes == 0 {
        Target::Regression(score)
    } else {
        // quantile-bin the scores into balanced classes + 2% label noise
        let mut sorted = score.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let k = spec.classes as usize;
        let cuts: Vec<f64> = (1..k)
            .map(|q| sorted[(q * spec.n_obs / k).min(spec.n_obs - 1)])
            .collect();
        let labels: Vec<u32> = score
            .iter()
            .map(|&s| {
                let mut c = 0u32;
                for &cut in &cuts {
                    if s > cut {
                        c += 1;
                    }
                }
                if rng.gen_bool(0.02) {
                    rng.gen_range(spec.classes as u64) as u32
                } else {
                    c
                }
            })
            .collect();
        Target::Classification {
            labels,
            classes: spec.classes,
        }
    };

    let features = columns
        .into_iter()
        .enumerate()
        .map(|(j, column)| Feature {
            name: if j < spec.n_numeric {
                format!("num_{j}")
            } else {
                format!("cat_{j}")
            },
            column,
        })
        .collect();

    let ds = Dataset {
        name: spec.name.to_string(),
        features,
        target,
    };
    debug_assert!(ds.validate().is_ok());
    ds
}

// --- Table 2 rows (paper §6). Sizes: (#obs, #vars) straight from Table 2. ---

/// Iris*: 150 obs, 4 numeric vars, 3 classes.
pub fn iris(seed: u64) -> Dataset {
    generate(
        &SynthSpec {
            name: "Iris*",
            n_obs: 150,
            n_numeric: 4,
            n_categorical: 0,
            max_levels: 0,
            n_rules: 6,
            informative_frac: 0.75,
            classes: 3,
            noise: 0.3,
        },
        seed,
    )
}

/// Wages*: 534 obs, 11 vars (mixed), binary classification.
pub fn wages(seed: u64) -> Dataset {
    generate(
        &SynthSpec {
            name: "Wages*",
            n_obs: 534,
            n_numeric: 5,
            n_categorical: 6,
            max_levels: 8,
            n_rules: 10,
            informative_frac: 0.6,
            classes: 2,
            noise: 0.4,
        },
        seed,
    )
}

/// Airfoil Self Noise⁺: 1503 obs, 5 numeric vars, regression.
pub fn airfoil_regression(seed: u64) -> Dataset {
    generate(
        &SynthSpec {
            name: "Airfoil Self Noise+",
            n_obs: 1503,
            n_numeric: 5,
            n_categorical: 0,
            max_levels: 0,
            n_rules: 12,
            informative_frac: 1.0,
            classes: 0,
            noise: 0.25,
        },
        seed,
    )
}

/// Airfoil Self Noise*: the regression problem binarized at the mean (§6).
pub fn airfoil_classification(seed: u64) -> Dataset {
    airfoil_regression(seed).binarize_by_mean().unwrap()
}

/// Bike Sharing⁺: 10886 obs, 11 vars, regression.
pub fn bike_sharing(seed: u64) -> Dataset {
    generate(
        &SynthSpec {
            name: "Bike Sharing+",
            n_obs: 10_886,
            n_numeric: 7,
            n_categorical: 4,
            max_levels: 12,
            n_rules: 16,
            informative_frac: 0.7,
            classes: 0,
            noise: 0.3,
        },
        seed,
    )
}

/// Naval Plants⁺: 11934 obs, 16 numeric vars, regression.
pub fn naval_regression(seed: u64) -> Dataset {
    generate(
        &SynthSpec {
            name: "Naval Plants+",
            n_obs: 11_934,
            n_numeric: 16,
            n_categorical: 0,
            max_levels: 0,
            n_rules: 14,
            informative_frac: 0.5,
            classes: 0,
            noise: 0.2,
        },
        seed,
    )
}

/// Naval Plants*: binarized.
pub fn naval_classification(seed: u64) -> Dataset {
    naval_regression(seed).binarize_by_mean().unwrap()
}

/// Shuttle*: 14500 obs, 9 numeric vars, 7 classes.
pub fn shuttle(seed: u64) -> Dataset {
    generate(
        &SynthSpec {
            name: "Shuttle*",
            n_obs: 14_500,
            n_numeric: 9,
            n_categorical: 0,
            max_levels: 0,
            n_rules: 12,
            informative_frac: 0.6,
            classes: 7,
            noise: 0.15,
        },
        seed,
    )
}

/// Forests* (Forest Cover Type): 15120 obs, 55 vars, 7 classes.
pub fn forests(seed: u64) -> Dataset {
    generate(
        &SynthSpec {
            name: "Forests*",
            n_obs: 15_120,
            n_numeric: 10,
            n_categorical: 45, // the real dataset's 44 one-hot soil/wilderness + 1
            max_levels: 2,
            n_rules: 20,
            informative_frac: 0.3,
            classes: 7,
            noise: 0.25,
        },
        seed,
    )
}

/// Adults*: 48842 obs, 14 vars (6 numeric, 8 categorical), 2 classes.
pub fn adults(seed: u64) -> Dataset {
    generate(
        &SynthSpec {
            name: "Adults*",
            n_obs: 48_842,
            n_numeric: 6,
            n_categorical: 8,
            max_levels: 14,
            n_rules: 16,
            informative_frac: 0.6,
            classes: 2,
            noise: 0.35,
        },
        seed,
    )
}

/// Liberty⁺: 50999 obs, 32 vars (16 numeric + 16 categorical), regression —
/// the paper's case-study dataset.
pub fn liberty_regression(seed: u64) -> Dataset {
    generate(
        &SynthSpec {
            name: "Liberty+",
            n_obs: 50_999,
            n_numeric: 16,
            n_categorical: 16,
            max_levels: 10,
            n_rules: 24,
            informative_frac: 0.5,
            classes: 0,
            noise: 0.4,
        },
        seed,
    )
}

/// Liberty*: binarized at the mean (the Table 1 case study).
pub fn liberty_classification(seed: u64) -> Dataset {
    liberty_regression(seed).binarize_by_mean().unwrap()
}

/// Otto*: 61878 obs, 94 numeric vars, 9 classes.
pub fn otto(seed: u64) -> Dataset {
    generate(
        &SynthSpec {
            name: "Otto*",
            n_obs: 61_878,
            n_numeric: 94,
            n_categorical: 0,
            max_levels: 0,
            n_rules: 28,
            informative_frac: 0.3,
            classes: 9,
            noise: 0.3,
        },
        seed,
    )
}

/// A Table-2 row: the generator plus the paper's reported numbers (MB) for
/// comparison in benches/EXPERIMENTS.md.
pub struct SuiteEntry {
    /// CLI dataset key (Table-2 row name).
    pub key: &'static str,
    /// Generator: seed → dataset.
    pub make: fn(u64) -> Dataset,
    /// Paper-reported "standard" baseline size, MB.
    pub paper_standard_mb: f64,
    /// Paper-reported "light" baseline size, MB.
    pub paper_light_mb: f64,
    /// Paper-reported compressed size, MB.
    pub paper_ours_mb: f64,
}

/// The full Table-2 suite in paper order.
pub fn table2_suite() -> Vec<SuiteEntry> {
    vec![
        SuiteEntry { key: "iris", make: iris, paper_standard_mb: 3.73, paper_light_mb: 0.082, paper_ours_mb: 0.013 },
        SuiteEntry { key: "wages", make: wages, paper_standard_mb: 15.78, paper_light_mb: 1.4, paper_ours_mb: 0.16 },
        SuiteEntry { key: "airfoil+", make: airfoil_regression, paper_standard_mb: 1.364, paper_light_mb: 0.49, paper_ours_mb: 0.34 },
        SuiteEntry { key: "airfoil*", make: airfoil_classification, paper_standard_mb: 1.26, paper_light_mb: 0.108, paper_ours_mb: 0.012 },
        SuiteEntry { key: "bike+", make: bike_sharing, paper_standard_mb: 7.69, paper_light_mb: 3.39, paper_ours_mb: 2.38 },
        SuiteEntry { key: "naval+", make: naval_regression, paper_standard_mb: 8.6, paper_light_mb: 3.05, paper_ours_mb: 2.15 },
        SuiteEntry { key: "naval*", make: naval_classification, paper_standard_mb: 8.5, paper_light_mb: 2.21, paper_ours_mb: 0.81 },
        SuiteEntry { key: "shuttle", make: shuttle, paper_standard_mb: 2.162, paper_light_mb: 0.28, paper_ours_mb: 0.049 },
        SuiteEntry { key: "forests", make: forests, paper_standard_mb: 9.136, paper_light_mb: 2.91, paper_ours_mb: 0.34 },
        SuiteEntry { key: "adults", make: adults, paper_standard_mb: 159.1, paper_light_mb: 41.6, paper_ours_mb: 7.3 },
        SuiteEntry { key: "liberty+", make: liberty_regression, paper_standard_mb: 733.7, paper_light_mb: 215.6, paper_ours_mb: 142.7 },
        SuiteEntry { key: "liberty*", make: liberty_classification, paper_standard_mb: 723.1, paper_light_mb: 96.5, paper_ours_mb: 12.43 },
        SuiteEntry { key: "otto", make: otto, paper_standard_mb: 209.1, paper_light_mb: 48.3, paper_ours_mb: 6.1 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Target;

    #[test]
    fn iris_shape() {
        let d = iris(1);
        d.validate().unwrap();
        assert_eq!(d.num_rows(), 150);
        assert_eq!(d.num_features(), 4);
        assert_eq!(d.target.num_classes(), 3);
    }

    #[test]
    fn liberty_shape_and_mix() {
        let d = liberty_regression(1);
        d.validate().unwrap();
        assert_eq!(d.num_rows(), 50_999);
        assert_eq!(d.num_features(), 32);
        let numeric = d.features.iter().filter(|f| f.column.is_numeric()).count();
        assert_eq!(numeric, 16);
        assert!(!d.target.is_classification());
        let c = liberty_classification(1);
        assert_eq!(c.target.num_classes(), 2);
    }

    #[test]
    fn deterministic_in_seed() {
        let a = airfoil_regression(9);
        let b = airfoil_regression(9);
        match (&a.target, &b.target) {
            (Target::Regression(x), Target::Regression(y)) => assert_eq!(x, y),
            _ => panic!(),
        }
        let c = airfoil_regression(10);
        match (&a.target, &c.target) {
            (Target::Regression(x), Target::Regression(y)) => assert_ne!(x, y),
            _ => panic!(),
        }
    }

    #[test]
    fn classes_are_all_present() {
        let d = shuttle(2);
        if let Target::Classification { labels, classes } = &d.target {
            let mut seen = vec![false; *classes as usize];
            for &l in labels {
                seen[l as usize] = true;
            }
            assert!(seen.iter().all(|&s| s), "all 7 classes should appear");
        } else {
            panic!()
        }
    }

    #[test]
    fn signal_is_learnable() {
        // a depth-limited stump committee should beat chance on iris-like data
        let d = iris(3);
        if let Target::Classification { labels, classes } = &d.target {
            // majority class frequency
            let mut counts = vec![0usize; *classes as usize];
            for &l in labels {
                counts[l as usize] += 1;
            }
            let maj = *counts.iter().max().unwrap() as f64 / labels.len() as f64;
            // quantile binning ⇒ roughly balanced
            assert!(maj < 0.55, "classes should be roughly balanced, maj={maj}");
        }
    }

    #[test]
    fn suite_covers_table2() {
        let suite = table2_suite();
        assert_eq!(suite.len(), 13);
        // spot-check row shapes cheaply (small ones only)
        let d = (suite[0].make)(1);
        assert_eq!(d.num_rows(), 150);
    }
}
