//! Minimal CSV loader for user-supplied real datasets.
//!
//! The reproduction runs on synthetic data (no UCI/Kaggle access offline),
//! but the library is usable on real data: `load_csv` infers column kinds
//! (numeric vs categorical) and builds a [`Dataset`].

use super::dataset::{Column, Dataset, Feature, Target};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// Which column is the target, and how to interpret it.
#[derive(Debug, Clone, Copy)]
pub enum TargetSpec {
    /// Column index, regression.
    Regression(usize),
    /// Column index, classification (levels inferred).
    Classification(usize),
}

/// Parse a CSV file (first row = header) into a [`Dataset`].
///
/// Column kind inference: a column where every non-empty cell parses as f64
/// is numeric; anything else is categorical with levels assigned in order of
/// first appearance. No quoting/escaping support — this is a data loader for
/// benchmark-style files, not a general CSV library.
pub fn load_csv(path: &Path, spec: TargetSpec) -> Result<Dataset> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse_csv(&text, path.file_stem().and_then(|s| s.to_str()).unwrap_or("csv"), spec)
}

/// Parse CSV text (exposed for tests).
pub fn parse_csv(text: &str, name: &str, spec: TargetSpec) -> Result<Dataset> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header: Vec<String> = lines
        .next()
        .context("empty csv")?
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let ncols = header.len();
    let mut cells: Vec<Vec<String>> = vec![Vec::new(); ncols];
    for (lineno, line) in lines.enumerate() {
        let row: Vec<&str> = line.split(',').map(|s| s.trim()).collect();
        if row.len() != ncols {
            bail!("line {}: {} cells, expected {ncols}", lineno + 2, row.len());
        }
        for (c, v) in row.iter().enumerate() {
            cells[c].push(v.to_string());
        }
    }
    let nrows = cells[0].len();
    if nrows == 0 {
        bail!("csv has a header but no data rows");
    }

    let target_col = match spec {
        TargetSpec::Regression(i) | TargetSpec::Classification(i) => i,
    };
    if target_col >= ncols {
        bail!("target column {target_col} out of range ({ncols} columns)");
    }

    let mut features = Vec::new();
    for c in 0..ncols {
        if c == target_col {
            continue;
        }
        features.push(Feature {
            name: header[c].clone(),
            column: infer_column(&cells[c]),
        });
    }

    let target = match spec {
        TargetSpec::Regression(_) => {
            let y: Result<Vec<f64>> = cells[target_col]
                .iter()
                .map(|s| s.parse::<f64>().with_context(|| format!("target value {s:?}")))
                .collect();
            Target::Regression(y?)
        }
        TargetSpec::Classification(_) => {
            let mut levels: HashMap<&str, u32> = HashMap::new();
            let labels: Vec<u32> = cells[target_col]
                .iter()
                .map(|s| {
                    let next = levels.len() as u32;
                    *levels.entry(s.as_str()).or_insert(next)
                })
                .collect();
            Target::Classification {
                labels,
                classes: levels.len() as u32,
            }
        }
    };

    let ds = Dataset {
        name: name.to_string(),
        features,
        target,
    };
    ds.validate()?;
    Ok(ds)
}

fn infer_column(cells: &[String]) -> Column {
    let all_numeric = cells.iter().all(|s| s.parse::<f64>().is_ok());
    if all_numeric {
        Column::Numeric(cells.iter().map(|s| s.parse().unwrap()).collect())
    } else {
        let mut levels: HashMap<&str, u32> = HashMap::new();
        let values: Vec<u32> = cells
            .iter()
            .map(|s| {
                let next = levels.len() as u32;
                *levels.entry(s.as_str()).or_insert(next)
            })
            .collect();
        Column::Categorical {
            values,
            levels: levels.len() as u32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CSV: &str = "x,color,y\n1.5,red,10\n2.5,blue,20\n3.5,red,30\n";

    #[test]
    fn parses_mixed_columns_regression() {
        let ds = parse_csv(CSV, "t", TargetSpec::Regression(2)).unwrap();
        assert_eq!(ds.num_rows(), 3);
        assert_eq!(ds.num_features(), 2);
        assert!(ds.features[0].column.is_numeric());
        assert!(!ds.features[1].column.is_numeric());
        match &ds.target {
            Target::Regression(y) => assert_eq!(y, &vec![10.0, 20.0, 30.0]),
            _ => panic!(),
        }
    }

    #[test]
    fn parses_classification_target() {
        let ds = parse_csv(CSV, "t", TargetSpec::Classification(1)).unwrap();
        match &ds.target {
            Target::Classification { labels, classes } => {
                assert_eq!(*classes, 2);
                assert_eq!(labels, &vec![0, 1, 0]);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn rejects_ragged_rows() {
        assert!(parse_csv("a,b\n1,2\n3\n", "t", TargetSpec::Regression(0)).is_err());
    }

    #[test]
    fn rejects_empty() {
        assert!(parse_csv("", "t", TargetSpec::Regression(0)).is_err());
        assert!(parse_csv("a,b\n", "t", TargetSpec::Regression(0)).is_err());
    }

    #[test]
    fn rejects_bad_target_index() {
        assert!(parse_csv(CSV, "t", TargetSpec::Regression(9)).is_err());
    }
}
