//! Baseline tree serializations (paper §6's comparators).

use crate::coding::bitio::{BitReader, BitWriter};
use crate::data::Dataset;
use crate::forest::{Fit, Forest, Node, Split, SplitValue, Tree};
use anyhow::{bail, Context, Result};

/// **Standard representation**: the verbose per-node record a Matlab
/// `compact(tree)` object keeps — node ids, parent and child pointers,
/// variable-name *strings* at every internal node, cut values, per-node
/// fitted values, node sizes and risk placeholders. Deliberately redundant:
/// this is the "best standard solution" starting point the paper gzip's.
pub fn standard_representation(forest: &Forest, ds: &Dataset) -> Vec<u8> {
    let mut out = Vec::new();
    // textual header, like a .mat-ish dump
    out.extend_from_slice(format!("RandomForest/{} trees\n", forest.trees.len()).as_bytes());
    for (t, tree) in forest.trees.iter().enumerate() {
        out.extend_from_slice(format!("tree {t} nodes {}\n", tree.nodes.len()).as_bytes());
        // parent pointers
        let mut parent = vec![-1i64; tree.nodes.len()];
        for (i, n) in tree.nodes.iter().enumerate() {
            if let Some((_, l, r)) = &n.split {
                parent[*l as usize] = i as i64;
                parent[*r as usize] = i as i64;
            }
        }
        for (i, n) in tree.nodes.iter().enumerate() {
            // node id, parent, children
            out.extend_from_slice(&(i as u64).to_le_bytes());
            out.extend_from_slice(&parent[i].to_le_bytes());
            match &n.split {
                Some((Split { feature, value }, l, r)) => {
                    out.extend_from_slice(&(*l as u64).to_le_bytes());
                    out.extend_from_slice(&(*r as u64).to_le_bytes());
                    // the variable *name string*, padded (Matlab cell array
                    // of CutPredictor strings)
                    let name = &ds.features[*feature as usize].name;
                    let mut buf = [0u8; 32];
                    let bytes = name.as_bytes();
                    buf[..bytes.len().min(32)].copy_from_slice(&bytes[..bytes.len().min(32)]);
                    out.extend_from_slice(&buf);
                    match value {
                        SplitValue::Numeric(v) => {
                            out.push(0);
                            out.extend_from_slice(&v.to_le_bytes());
                            out.extend_from_slice(&0u64.to_le_bytes()); // unused mask slot
                        }
                        SplitValue::Categorical(m) => {
                            out.push(1);
                            out.extend_from_slice(&0f64.to_le_bytes()); // unused cut slot
                            out.extend_from_slice(&m.to_le_bytes());
                        }
                    }
                }
                None => {
                    out.extend_from_slice(&u64::MAX.to_le_bytes());
                    out.extend_from_slice(&u64::MAX.to_le_bytes());
                    out.extend_from_slice(&[0u8; 32]);
                    out.push(2);
                    out.extend_from_slice(&0f64.to_le_bytes());
                    out.extend_from_slice(&0u64.to_le_bytes());
                }
            }
            // fit (double at every node, Matlab-style), plus NodeSize /
            // NodeRisk placeholder doubles a compact tree retains
            let fit = match n.fit {
                Fit::Regression(v) => v,
                Fit::Class(c) => c as f64,
            };
            out.extend_from_slice(&fit.to_le_bytes());
            out.extend_from_slice(&(tree.nodes.len() as f64).to_le_bytes());
            out.extend_from_slice(&0f64.to_le_bytes());
        }
    }
    out
}

/// Per-component byte sizes of the light representation (the paper's Table 1
/// "light comp." row is this, gzip'd per component).
#[derive(Debug, Clone, Copy, Default)]
pub struct LightSections {
    /// Tree-structure bytes.
    pub structure: u64,
    /// Variable-name bytes.
    pub var_names: u64,
    /// Split-value bytes.
    pub split_values: u64,
    /// Fit bytes.
    pub fits: u64,
}

/// **Light representation**: prediction-only fields, strings → numeric ids.
/// Layout (per forest): header, then four *separate* component streams so
/// the Table-1-style breakdown is measurable; returns the raw bytes plus
/// the per-component sizes (pre-gzip).
pub fn light_representation(forest: &Forest) -> (Vec<u8>, LightSections) {
    let mut structure = BitWriter::new();
    let mut vars = BitWriter::new();
    let mut splits = BitWriter::new();
    let mut fits = BitWriter::new();

    structure.write_varint(forest.trees.len() as u64);
    structure.write_bits(forest.classification as u64, 8);
    structure.write_varint(forest.classes as u64);
    for tree in &forest.trees {
        structure.write_varint(tree.nodes.len() as u64);
        for n in &tree.nodes {
            structure.write_bit(!n.is_leaf());
            if let Some((split, _, _)) = &n.split {
                vars.write_varint(split.feature as u64);
                // 1-bit kind tag keeps the stream self-describing
                match &split.value {
                    SplitValue::Numeric(v) => {
                        splits.write_bit(false);
                        splits.write_bits(v.to_bits(), 64);
                    }
                    SplitValue::Categorical(m) => {
                        splits.write_bit(true);
                        splits.write_varint(*m);
                    }
                }
            }
            match n.fit {
                Fit::Regression(v) => fits.write_bits(v.to_bits(), 64),
                Fit::Class(c) => fits.write_varint(c as u64),
            }
        }
    }

    let sections = LightSections {
        structure: (structure.bit_len() + 7) / 8,
        var_names: (vars.bit_len() + 7) / 8,
        split_values: (splits.bit_len() + 7) / 8,
        fits: (fits.bit_len() + 7) / 8,
    };
    let mut out = BitWriter::new();
    for part in [&structure, &vars, &splits, &fits] {
        out.write_varint(part.bit_len());
        out.align_byte();
        out.append(part);
        out.align_byte();
    }
    (out.into_bytes(), sections)
}

/// Decode the light representation (round-trip proof of losslessness).
pub fn light_decode(bytes: &[u8]) -> Result<Forest> {
    let mut r = BitReader::new(bytes);
    let mut parts = Vec::new();
    for _ in 0..4 {
        let bits = r.read_varint().context("light: part length")?;
        r.align_byte();
        let start = r.bit_pos();
        r.seek_bits(start + bits);
        r.align_byte();
        parts.push((start, bits));
    }
    let (s_off, _) = parts[0];
    let (v_off, _) = parts[1];
    let (p_off, _) = parts[2];
    let (f_off, _) = parts[3];
    let mut sr = BitReader::new(bytes);
    sr.seek_bits(s_off);
    let mut vr = BitReader::new(bytes);
    vr.seek_bits(v_off);
    let mut pr = BitReader::new(bytes);
    pr.seek_bits(p_off);
    let mut fr = BitReader::new(bytes);
    fr.seek_bits(f_off);

    let n_trees = sr.read_varint().context("light: trees")? as usize;
    let classification = sr.read_bits(8).context("light: kind")? != 0;
    let classes = sr.read_varint().context("light: classes")? as u32;
    let mut trees = Vec::with_capacity(n_trees);
    for _ in 0..n_trees {
        let n = sr.read_varint().context("light: nodes")? as usize;
        if n == 0 {
            bail!("light: empty tree");
        }
        let mut leaf_flags = Vec::with_capacity(n);
        for _ in 0..n {
            leaf_flags.push(!sr.read_bit().context("light: structure bit")?);
        }
        // rebuild preorder children from the leaf/internal flags (the Zaks
        // property again)
        let mut nodes: Vec<Node> = Vec::with_capacity(n);
        build_light(
            &leaf_flags,
            &mut 0,
            &mut nodes,
            &mut vr,
            &mut pr,
            &mut fr,
            classification,
        )?;
        if nodes.len() != n {
            bail!("light: structure mismatch");
        }
        trees.push(Tree { nodes });
    }
    Ok(Forest { trees, classification, classes })
}

fn build_light(
    leaf: &[bool],
    pos: &mut usize,
    nodes: &mut Vec<Node>,
    vr: &mut BitReader,
    pr: &mut BitReader,
    fr: &mut BitReader,
    classification: bool,
) -> Result<u32> {
    let idx = *pos;
    if idx >= leaf.len() {
        bail!("light: truncated structure");
    }
    *pos += 1;
    let my = nodes.len() as u32;
    // placeholder; fill after recursion
    nodes.push(Node { split: None, fit: Fit::Class(0) });
    let fit = if classification {
        Fit::Class(fr.read_varint().context("light: fit")? as u32)
    } else {
        Fit::Regression(f64::from_bits(fr.read_bits(64).context("light: fit")?))
    };
    if leaf[idx] {
        nodes[my as usize].fit = fit;
        return Ok(my);
    }
    let feature = vr.read_varint().context("light: feature")? as u32;
    // 1-bit kind tag written by the encoder (the light format carries no
    // per-feature schema, so the stream must be self-describing)
    let is_mask = pr.read_bit().context("light: split tag")?;
    let value = if is_mask {
        SplitValue::Categorical(pr.read_varint().context("light: mask")?)
    } else {
        SplitValue::Numeric(f64::from_bits(pr.read_bits(64).context("light: cut")?))
    };
    let l = build_light(leaf, pos, nodes, vr, pr, fr, classification)?;
    let r = build_light(leaf, pos, nodes, vr, pr, fr, classification)?;
    nodes[my as usize] = Node { split: Some((Split { feature, value }, l, r)), fit };
    Ok(my)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::forest::ForestParams;

    #[test]
    fn standard_is_bigger_than_light() {
        let ds = synthetic::wages(61);
        let f = crate::forest::Forest::train(&ds, &ForestParams::classification(5), 3);
        let std_bytes = standard_representation(&f, &ds);
        let (light_bytes, sections) = light_representation(&f);
        assert!(std_bytes.len() > 2 * light_bytes.len());
        assert!(sections.structure > 0 && sections.fits > 0);
    }

    #[test]
    fn light_roundtrip_lossless() {
        for (name, cls) in [("reg", false), ("cls", true)] {
            let f = if cls {
                let ds = synthetic::iris(63);
                crate::forest::Forest::train(&ds, &ForestParams::classification(4), 5)
            } else {
                let ds = synthetic::airfoil_regression(63);
                crate::forest::Forest::train(&ds, &ForestParams::regression(3), 5)
            };
            let (bytes, _) = light_representation(&f);
            let back = light_decode(&bytes).unwrap();
            assert!(back.identical(&f), "{name} light round-trip");
        }
    }

    #[test]
    fn light_roundtrip_with_categoricals() {
        let ds = synthetic::wages(64);
        let f = crate::forest::Forest::train(&ds, &ForestParams::classification(4), 6);
        let (bytes, _) = light_representation(&f);
        assert!(light_decode(&bytes).unwrap().identical(&f));
    }

    #[test]
    fn light_decode_rejects_truncation() {
        let ds = synthetic::iris(65);
        let f = crate::forest::Forest::train(&ds, &ForestParams::classification(2), 7);
        let (bytes, _) = light_representation(&f);
        assert!(light_decode(&bytes[..bytes.len() / 3]).is_err());
    }

    #[test]
    fn gzip_narrows_but_keeps_gap() {
        let ds = synthetic::iris(62);
        let f = crate::forest::Forest::train(&ds, &ForestParams::classification(8), 4);
        let std_gz = crate::baseline::gzip::gzip(&standard_representation(&f, &ds));
        let light_gz = crate::baseline::gzip::gzip(&light_representation(&f).0);
        assert!(
            std_gz.len() > light_gz.len(),
            "standard ({}) must stay above light ({}) after gzip",
            std_gz.len(),
            light_gz.len()
        );
    }
}
