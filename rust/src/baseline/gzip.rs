//! The off-the-shelf comparator codecs, built on the in-tree substrates.
//!
//! The build environment is offline (no `flate2`/`zstd` crates), so the
//! paper's "gzip" step is stood in for by the same DEFLATE-class recipe:
//! LZSS with a hash-chain match finder ([`crate::coding::lz`]), and — for
//! the stronger `zstd`-role comparator — a second order-0 canonical-Huffman
//! pass over the LZSS stream. Both are honest general-purpose compressors
//! with no knowledge of the forest structure, which is all the baseline
//! comparison needs; the API matches the previous `flate2`/`zstd` wrappers
//! so callers are unchanged.

use crate::coding::bitio::{BitReader, BitWriter};
use crate::coding::huffman::HuffmanCode;
use crate::coding::lz;
use anyhow::{bail, Context, Result};

const GZ_MAGIC: &[u8; 4] = b"RFGZ";
const ZS_MAGIC: &[u8; 4] = b"RFZS";

/// gzip-role compressor: LZSS over the raw bytes.
pub fn gzip(data: &[u8]) -> Vec<u8> {
    let mut out = GZ_MAGIC.to_vec();
    out.extend(lz::compress_to_bytes(data));
    out
}

/// Inverse of [`gzip`]: decompress an `RFGZ` stream.
pub fn gunzip(data: &[u8]) -> Result<Vec<u8>> {
    let Some(body) = data.strip_prefix(&GZ_MAGIC[..]) else {
        bail!("gunzip: not an RFGZ stream");
    };
    lz::decompress_from_bytes(body).context("gunzip")
}

/// zstd-role compressor (the ablation bench's stronger comparator): LZSS,
/// then an order-0 Huffman pass over the LZSS byte stream. Falls back to
/// the plain LZSS bytes when the Huffman dictionary does not pay (tiny or
/// already-dense streams); a mode byte records the choice.
pub fn zstd_strong(data: &[u8]) -> Vec<u8> {
    let lzb = lz::compress_to_bytes(data);
    let huff = huffman_pass(&lzb);
    let mut out = ZS_MAGIC.to_vec();
    match huff {
        Ok(h) if h.len() < lzb.len() => {
            out.push(0);
            out.extend(h);
        }
        _ => {
            out.push(1);
            out.extend(lzb);
        }
    }
    out
}

fn huffman_pass(lzb: &[u8]) -> Result<Vec<u8>> {
    let mut counts = [0u64; 256];
    for &b in lzb {
        counts[b as usize] += 1;
    }
    let weights: Vec<f64> = counts.iter().map(|&c| c as f64).collect();
    let code = HuffmanCode::from_weights(&weights)?;
    let mut w = BitWriter::new();
    code.write_dict(&mut w);
    w.write_varint(lzb.len() as u64);
    for &b in lzb {
        code.encode(b as u32, &mut w)?;
    }
    Ok(w.into_bytes())
}

/// Inverse of [`zstd_strong`]: decompress an `RFZS` stream.
pub fn unzstd(data: &[u8]) -> Result<Vec<u8>> {
    let Some(body) = data.strip_prefix(&ZS_MAGIC[..]) else {
        bail!("unzstd: not an RFZS stream");
    };
    let (&mode, rest) = body.split_first().context("unzstd: empty stream")?;
    let lzb = match mode {
        0 => {
            let mut r = BitReader::new(rest);
            let code = HuffmanCode::read_dict(&mut r)?;
            let n = r.read_varint().context("unzstd: length")? as usize;
            // every symbol costs ≥ 1 bit, so the stream itself bounds the
            // count — rejects crafted headers before any allocation
            if n > rest.len().saturating_mul(8) {
                bail!("unzstd: length {n} exceeds the stream");
            }
            let syms = code.decoder().decode_all(&mut r, n).context("unzstd: payload")?;
            syms.into_iter().map(|s| s as u8).collect()
        }
        1 => rest.to_vec(),
        v => bail!("unzstd: unknown mode {v}"),
    };
    lz::decompress_from_bytes(&lzb).context("unzstd")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gzip_roundtrip() {
        let data = b"the quick brown fox jumps over the lazy dog".repeat(50);
        let c = gzip(&data);
        assert!(c.len() < data.len());
        assert_eq!(gunzip(&c).unwrap(), data);
    }

    #[test]
    fn zstd_roundtrip() {
        let data = vec![7u8; 10_000];
        let c = zstd_strong(&data);
        assert!(c.len() < 100);
        assert_eq!(unzstd(&c).unwrap(), data);
    }

    #[test]
    fn gunzip_garbage_errors() {
        assert!(gunzip(b"not gzip at all").is_err());
        assert!(unzstd(b"not zstd either").is_err());
    }

    #[test]
    fn zstd_roundtrips_both_modes() {
        // dense/short input exercises the mode-1 (no-Huffman) fallback;
        // long text exercises mode 0
        for data in [b"x".to_vec(), b"abcdefgh".repeat(400)] {
            let c = zstd_strong(&data);
            assert_eq!(unzstd(&c).unwrap(), data, "len {}", data.len());
        }
    }

    #[test]
    fn empty_input_roundtrips() {
        assert_eq!(gunzip(&gzip(b"")).unwrap(), Vec::<u8>::new());
        assert_eq!(unzstd(&zstd_strong(b"")).unwrap(), Vec::<u8>::new());
    }
}
