//! gzip (and zstd, as an ablation) wrappers over `flate2`/`zstd`.

use anyhow::{Context, Result};
use std::io::{Read, Write};

/// gzip-compress at the default level (6), like the paper's off-the-shelf
/// `gzip` step.
pub fn gzip(data: &[u8]) -> Vec<u8> {
    let mut enc =
        flate2::write::GzEncoder::new(Vec::new(), flate2::Compression::default());
    enc.write_all(data).expect("in-memory write");
    enc.finish().expect("in-memory finish")
}

pub fn gunzip(data: &[u8]) -> Result<Vec<u8>> {
    let mut dec = flate2::read::GzDecoder::new(data);
    let mut out = Vec::new();
    dec.read_to_end(&mut out).context("gunzip")?;
    Ok(out)
}

/// zstd at level 19 — a stronger general-purpose comparator for the
/// ablation bench (how much of our gain is just a better entropy coder?).
pub fn zstd_strong(data: &[u8]) -> Vec<u8> {
    zstd::encode_all(data, 19).expect("in-memory zstd")
}

pub fn unzstd(data: &[u8]) -> Result<Vec<u8>> {
    zstd::decode_all(data).context("unzstd")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gzip_roundtrip() {
        let data = b"the quick brown fox jumps over the lazy dog".repeat(50);
        let c = gzip(&data);
        assert!(c.len() < data.len());
        assert_eq!(gunzip(&c).unwrap(), data);
    }

    #[test]
    fn zstd_roundtrip() {
        let data = vec![7u8; 10_000];
        let c = zstd_strong(&data);
        assert!(c.len() < 100);
        assert_eq!(unzstd(&c).unwrap(), data);
    }

    #[test]
    fn gunzip_garbage_errors() {
        assert!(gunzip(b"not gzip at all").is_err());
    }
}
