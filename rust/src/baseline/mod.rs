//! The paper's two baseline compressors (§6):
//!
//! * **standard** — a verbose tree serialization carrying the bookkeeping a
//!   Matlab `compact(tree)` object keeps (node ids, parent/child pointers,
//!   per-node variable-name *strings*, per-node fits and summary fields),
//!   followed by gzip;
//! * **light**    — only the fields needed for prediction, strings replaced
//!   by numeric ids ("elementary adjustments" per the paper), followed by
//!   gzip.
//!
//! Both are lossless (round-trip tested) so the comparison with Algorithm 1
//! is apples-to-apples.

pub mod gzip;
pub mod serialize;

pub use serialize::{light_representation, standard_representation, LightSections};
