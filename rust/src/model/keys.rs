//! Conditioning keys for the node/fit models.
//!
//! The paper's relaxation conditions each node on `(depth, father's variable
//! name)`. The `ablations` bench also measures cheaper conditionings
//! (depth-only, unconditional) to quantify what the relaxation buys, so the
//! key computation is parameterized by [`ModelConditioning`].

/// Father value used at the root (no father). Chosen as `u32::MAX` so it can
/// never collide with a feature index.
pub const ROOT_FATHER: u32 = u32::MAX;

/// A model-conditioning context: which empirical distribution a node's
/// symbol is drawn from.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ContextKey {
    /// Node depth (root = 0), saturating at `u16::MAX`.
    pub depth: u16,
    /// Father's feature index, or [`ROOT_FATHER`].
    pub father: u32,
}

impl ContextKey {
    /// Key for a node at `depth` whose father split on `father`.
    pub fn new(depth: u32, father: Option<u32>) -> Self {
        ContextKey {
            depth: depth.min(u16::MAX as u32) as u16,
            father: father.unwrap_or(ROOT_FATHER),
        }
    }
}

/// How much context the models condition on (paper default:
/// [`ModelConditioning::DepthFather`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelConditioning {
    /// `(depth, father)` — the paper's relaxation (§3.2.2).
    DepthFather,
    /// depth only — what §6 reports the clustering usually collapses to.
    DepthOnly,
    /// a single unconditional model (ablation baseline).
    None,
}

impl ModelConditioning {
    /// Project a raw context onto this conditioning level. Projected keys
    /// still use the `ContextKey` type; unused components are zeroed.
    pub fn project(&self, key: ContextKey) -> ContextKey {
        match self {
            ModelConditioning::DepthFather => key,
            ModelConditioning::DepthOnly => ContextKey { depth: key.depth, father: 0 },
            ModelConditioning::None => ContextKey { depth: 0, father: 0 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn root_key() {
        let k = ContextKey::new(0, None);
        assert_eq!(k.depth, 0);
        assert_eq!(k.father, ROOT_FATHER);
    }

    #[test]
    fn depth_saturates() {
        let k = ContextKey::new(1 << 20, Some(3));
        assert_eq!(k.depth, u16::MAX);
        assert_eq!(k.father, 3);
    }

    #[test]
    fn projections() {
        let k = ContextKey::new(7, Some(2));
        assert_eq!(ModelConditioning::DepthFather.project(k), k);
        assert_eq!(
            ModelConditioning::DepthOnly.project(k),
            ContextKey { depth: 7, father: 0 }
        );
        assert_eq!(
            ModelConditioning::None.project(k),
            ContextKey { depth: 0, father: 0 }
        );
    }
}
