//! Probabilistic models of forest nodes (paper §3.2–3.3, Algorithm 1 lines
//! 4–21).
//!
//! The full conditional structure of eq. (2) is exponential in depth, so the
//! paper relaxes it: a node's models are conditioned on **(its depth, its
//! father's variable name)** only. This module extracts the corresponding
//! empirical conditional distributions from a trained forest:
//!
//! * `P_vn(variable name | depth, father)` — one table, alphabet = features
//! * `P_sv(split value  | variable, depth, father)` — one table per feature,
//!   alphabet = the feature's observed split values (rank-coded)
//! * `P_fit(fit | depth, father)` — one table, alphabet = classes or the
//!   observed distinct regression fit values
//!
//! [`keys`] defines the conditioning key, [`extract`] the tables and the
//! per-feature/fit value alphabets shared by encoder and decoder.

pub mod extract;
pub mod keys;

pub use extract::{ForestModels, SplitAlphabet, ValueAlphabets};
pub use keys::{ContextKey, ModelConditioning, ROOT_FATHER};
