//! Extraction of the empirical conditional distributions (Algorithm 1,
//! lines 4–21) and of the value alphabets shared by encoder and decoder.
//!
//! Two passes over the forest:
//!
//! 1. **Alphabet pass** — collect, per feature, the distinct split values
//!    used anywhere in the forest (sorted, so a numeric split value is coded
//!    as its *rank*; the paper codes it as an observation index, which is the
//!    same idea with the dataset as the implicit table — a standalone
//!    decompressor needs the table itself, which the container stores), and
//!    the distinct regression fit values (bit-exact f64s).
//! 2. **Count pass** — accumulate the conditional count tables keyed by
//!    [`ContextKey`]; parallelized as a map-reduce over trees.

use super::keys::{ContextKey, ModelConditioning};
use crate::data::{Column, Dataset};
use crate::forest::{Fit, Forest, SplitValue};
use crate::util::threads::parallel_fold;
use anyhow::{bail, Result};
use std::collections::{BTreeMap, HashMap};

/// Split-value alphabet of one feature: the distinct values observed across
/// the whole forest, in sorted order (rank = symbol).
#[derive(Debug, Clone, PartialEq)]
pub enum SplitAlphabet {
    /// Sorted distinct numeric thresholds.
    Numeric(Vec<f64>),
    /// Sorted distinct category masks.
    Categorical(Vec<u64>),
}

impl SplitAlphabet {
    /// Number of distinct symbols in the alphabet.
    pub fn len(&self) -> usize {
        match self {
            SplitAlphabet::Numeric(v) => v.len(),
            SplitAlphabet::Categorical(v) => v.len(),
        }
    }

    /// Whether the alphabet is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Symbol (rank) of a split value; the value must be present.
    pub fn symbol_of(&self, value: &SplitValue) -> Option<u32> {
        match (self, value) {
            (SplitAlphabet::Numeric(tbl), SplitValue::Numeric(v)) => tbl
                .binary_search_by(|x| x.partial_cmp(v).unwrap())
                .ok()
                .map(|i| i as u32),
            (SplitAlphabet::Categorical(tbl), SplitValue::Categorical(m)) => {
                tbl.binary_search(m).ok().map(|i| i as u32)
            }
            _ => None,
        }
    }

    /// Split value of a symbol.
    pub fn value_of(&self, sym: u32) -> SplitValue {
        match self {
            SplitAlphabet::Numeric(tbl) => SplitValue::Numeric(tbl[sym as usize]),
            SplitAlphabet::Categorical(tbl) => SplitValue::Categorical(tbl[sym as usize]),
        }
    }
}

/// All value alphabets of a forest: per-feature split alphabets plus the fit
/// alphabet (distinct f64 bit patterns for regression; classes are their own
/// alphabet for classification).
#[derive(Debug, Clone, PartialEq)]
pub struct ValueAlphabets {
    /// Per-feature split-value alphabets.
    pub splits: Vec<SplitAlphabet>,
    /// Sorted distinct regression fit values (by bit pattern order of the
    /// underlying f64s sorted numerically); empty for classification.
    pub fits: Vec<f64>,
}

impl ValueAlphabets {
    /// Sorted unique values of a numeric column. In the paper's
    /// dataset-indexed mode (§3.2.2) a numeric split value is stored as its
    /// rank within this list, which encoder and decoder regenerate
    /// identically from the training data instead of shipping f64 tables
    /// (the paper's `α = log₂(n) + C` accounting).
    pub fn column_unique(ds: &Dataset, feature: usize) -> Result<Vec<f64>> {
        match &ds.features[feature].column {
            Column::Numeric(v) => {
                let mut vals = v.clone();
                vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
                vals.dedup_by(|a, b| a.to_bits() == b.to_bits());
                Ok(vals)
            }
            Column::Categorical { .. } => bail!("feature {feature} is categorical"),
        }
    }

    /// Alphabet pass over the forest (self-contained mode: numeric
    /// alphabets are the thresholds actually used, stored in the container).
    pub fn collect(forest: &Forest, ds: &Dataset) -> Result<Self> {
        let d = ds.num_features();
        // distinct split values per feature
        let mut num_vals: Vec<Vec<u64>> = vec![Vec::new(); d]; // f64 bits, dedup later
        let mut cat_vals: Vec<Vec<u64>> = vec![Vec::new(); d];
        let mut fit_bits: Vec<u64> = Vec::new();
        for tree in &forest.trees {
            for node in &tree.nodes {
                if let Some((split, _, _)) = &node.split {
                    let f = split.feature as usize;
                    if f >= d {
                        bail!("split feature {f} out of range");
                    }
                    match &split.value {
                        SplitValue::Numeric(v) => num_vals[f].push(v.to_bits()),
                        SplitValue::Categorical(m) => cat_vals[f].push(*m),
                    }
                }
                if let Fit::Regression(v) = node.fit {
                    fit_bits.push(v.to_bits());
                }
            }
        }
        let mut splits = Vec::with_capacity(d);
        for f in 0..d {
            match &ds.features[f].column {
                Column::Numeric(_) => {
                    if !cat_vals[f].is_empty() {
                        bail!("categorical split on numeric feature {f}");
                    }
                    let mut vals: Vec<f64> =
                        num_vals[f].iter().map(|&b| f64::from_bits(b)).collect();
                    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
                    vals.dedup_by(|a, b| a.to_bits() == b.to_bits());
                    splits.push(SplitAlphabet::Numeric(vals));
                }
                Column::Categorical { .. } => {
                    if !num_vals[f].is_empty() {
                        bail!("numeric split on categorical feature {f}");
                    }
                    let mut vals = cat_vals[f].clone();
                    vals.sort();
                    vals.dedup();
                    splits.push(SplitAlphabet::Categorical(vals));
                }
            }
        }
        let fits = {
            let mut vals: Vec<f64> = fit_bits.iter().map(|&b| f64::from_bits(b)).collect();
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            vals.dedup_by(|a, b| a.to_bits() == b.to_bits());
            vals
        };
        Ok(ValueAlphabets { splits, fits })
    }

    /// Fit symbol of a node fit.
    pub fn fit_symbol(&self, fit: &Fit) -> u32 {
        match fit {
            Fit::Class(c) => *c,
            Fit::Regression(v) => self
                .fits
                .binary_search_by(|x| x.partial_cmp(v).unwrap())
                .expect("fit value must be in the alphabet") as u32,
        }
    }

    /// Fit alphabet size for a forest.
    pub fn fit_alphabet_size(&self, forest: &Forest) -> usize {
        if forest.classification {
            forest.classes as usize
        } else {
            self.fits.len()
        }
    }
}

/// A set of conditional count tables keyed by [`ContextKey`]. `BTreeMap`
/// keeps key iteration deterministic (clustering and container layout depend
/// on the order).
pub type CountTable = BTreeMap<ContextKey, Vec<u64>>;

/// The extracted models of a forest.
#[derive(Debug, Clone)]
pub struct ForestModels {
    /// `P(variable name | key)` — alphabet = number of features.
    pub var_names: CountTable,
    /// Per-feature `P(split rank | key)` — alphabet = that feature's
    /// [`SplitAlphabet`] size.
    pub splits: Vec<CountTable>,
    /// `P(fit symbol | key)` — alphabet = classes or distinct fit values.
    pub fits: CountTable,
    /// The conditioning level the keys were projected with.
    pub conditioning: ModelConditioning,
}

impl ForestModels {
    /// Count pass (Algorithm 1 lines 7–21), parallelized over trees.
    pub fn extract(
        forest: &Forest,
        alphabets: &ValueAlphabets,
        conditioning: ModelConditioning,
        workers: usize,
    ) -> ForestModels {
        let d = alphabets.splits.len();
        let fit_alpha = alphabets.fit_alphabet_size(forest);

        #[derive(Clone)]
        struct Partial {
            var_names: HashMap<ContextKey, Vec<u64>>,
            splits: Vec<HashMap<ContextKey, Vec<u64>>>,
            fits: HashMap<ContextKey, Vec<u64>>,
        }

        let fold = |trees: &[crate::forest::Tree]| -> Partial {
            let mut p = Partial {
                var_names: HashMap::new(),
                splits: vec![HashMap::new(); d],
                fits: HashMap::new(),
            };
            for tree in trees {
                tree.visit_preorder(|_, node, depth, father| {
                    let key = conditioning.project(ContextKey::new(depth, father));
                    if let Some((split, _, _)) = &node.split {
                        let f = split.feature as usize;
                        p.var_names
                            .entry(key)
                            .or_insert_with(|| vec![0; d])[f] += 1;
                        let sym = alphabets.splits[f]
                            .symbol_of(&split.value)
                            .expect("split value in alphabet");
                        let tbl = p.splits[f]
                            .entry(key)
                            .or_insert_with(|| vec![0; alphabets.splits[f].len()]);
                        tbl[sym as usize] += 1;
                    }
                    let fsym = alphabets.fit_symbol(&node.fit) as usize;
                    p.fits.entry(key).or_insert_with(|| vec![0; fit_alpha])[fsym] += 1;
                });
            }
            p
        };

        let merge_into = |dst: &mut HashMap<ContextKey, Vec<u64>>,
                          src: HashMap<ContextKey, Vec<u64>>| {
            for (k, v) in src {
                match dst.entry(k) {
                    std::collections::hash_map::Entry::Occupied(mut e) => {
                        for (a, b) in e.get_mut().iter_mut().zip(&v) {
                            *a += b;
                        }
                    }
                    std::collections::hash_map::Entry::Vacant(e) => {
                        e.insert(v);
                    }
                }
            }
        };

        let merged = parallel_fold(&forest.trees, workers, fold, |mut a, b| {
            merge_into(&mut a.var_names, b.var_names);
            for (da, sb) in a.splits.iter_mut().zip(b.splits) {
                merge_into(da, sb);
            }
            merge_into(&mut a.fits, b.fits);
            a
        })
        .unwrap_or(Partial {
            var_names: HashMap::new(),
            splits: vec![HashMap::new(); d],
            fits: HashMap::new(),
        });

        ForestModels {
            var_names: merged.var_names.into_iter().collect(),
            splits: merged.splits.into_iter().map(|m| m.into_iter().collect()).collect(),
            fits: merged.fits.into_iter().collect(),
            conditioning,
        }
    }

    /// Total node count represented in the var-name table (= internal nodes).
    pub fn total_internal(&self) -> u64 {
        self.var_names.values().flat_map(|v| v.iter()).sum()
    }

    /// Total fit symbols (= all nodes).
    pub fn total_fits(&self) -> u64 {
        self.fits.values().flat_map(|v| v.iter()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::forest::{Forest, ForestParams};
    use crate::model::keys::ROOT_FATHER;

    fn small_forest() -> (crate::data::Dataset, Forest) {
        let ds = synthetic::wages(3);
        let f = Forest::train(&ds, &ForestParams::classification(6), 11);
        (ds, f)
    }

    #[test]
    fn alphabets_cover_every_split() {
        let (ds, f) = small_forest();
        let al = ValueAlphabets::collect(&f, &ds).unwrap();
        assert_eq!(al.splits.len(), ds.num_features());
        for t in &f.trees {
            for n in &t.nodes {
                if let Some((s, _, _)) = &n.split {
                    assert!(
                        al.splits[s.feature as usize].symbol_of(&s.value).is_some(),
                        "every used split value must be in the alphabet"
                    );
                }
            }
        }
        // classification ⇒ no fit table
        assert!(al.fits.is_empty());
    }

    #[test]
    fn alphabet_symbols_roundtrip() {
        let (ds, f) = small_forest();
        let al = ValueAlphabets::collect(&f, &ds).unwrap();
        for t in &f.trees {
            for n in &t.nodes {
                if let Some((s, _, _)) = &n.split {
                    let a = &al.splits[s.feature as usize];
                    let sym = a.symbol_of(&s.value).unwrap();
                    assert_eq!(a.value_of(sym), s.value);
                }
            }
        }
    }

    #[test]
    fn regression_fit_alphabet() {
        let ds = synthetic::airfoil_regression(4);
        let f = Forest::train(&ds, &ForestParams::regression(3), 5);
        let al = ValueAlphabets::collect(&f, &ds).unwrap();
        assert!(!al.fits.is_empty());
        // every node fit must be representable and bit-exact
        for t in &f.trees {
            for n in &t.nodes {
                let sym = al.fit_symbol(&n.fit);
                let back = al.fits[sym as usize];
                match n.fit {
                    Fit::Regression(v) => assert_eq!(v.to_bits(), back.to_bits()),
                    _ => panic!(),
                }
            }
        }
    }

    #[test]
    fn count_tables_are_consistent() {
        let (ds, f) = small_forest();
        let al = ValueAlphabets::collect(&f, &ds).unwrap();
        let m = ForestModels::extract(&f, &al, ModelConditioning::DepthFather, 1);
        // total internal nodes across tables equals forest internal nodes
        let internal: usize = f.trees.iter().map(|t| t.internal_count()).sum();
        assert_eq!(m.total_internal(), internal as u64);
        // total fits = total nodes (fits at every node)
        assert_eq!(m.total_fits(), f.total_nodes() as u64);
        // split tables per feature sum to var-name counts of that feature
        for (fidx, tbl) in m.splits.iter().enumerate() {
            let from_splits: u64 = tbl.values().flat_map(|v| v.iter()).sum();
            let from_vars: u64 = m.var_names.values().map(|v| v[fidx]).sum();
            assert_eq!(from_splits, from_vars, "feature {fidx}");
        }
        // root context exists with depth 0 / ROOT_FATHER
        assert!(m
            .var_names
            .keys()
            .any(|k| k.depth == 0 && k.father == ROOT_FATHER));
    }

    #[test]
    fn extraction_parallel_equals_sequential() {
        let (ds, f) = small_forest();
        let al = ValueAlphabets::collect(&f, &ds).unwrap();
        let a = ForestModels::extract(&f, &al, ModelConditioning::DepthFather, 1);
        let b = ForestModels::extract(&f, &al, ModelConditioning::DepthFather, 4);
        assert_eq!(a.var_names, b.var_names);
        assert_eq!(a.splits, b.splits);
        assert_eq!(a.fits, b.fits);
    }

    #[test]
    fn conditioning_projection_reduces_keys() {
        let (ds, f) = small_forest();
        let al = ValueAlphabets::collect(&f, &ds).unwrap();
        let full = ForestModels::extract(&f, &al, ModelConditioning::DepthFather, 1);
        let depth = ForestModels::extract(&f, &al, ModelConditioning::DepthOnly, 1);
        let none = ForestModels::extract(&f, &al, ModelConditioning::None, 1);
        assert!(depth.var_names.len() <= full.var_names.len());
        assert_eq!(none.var_names.len(), 1);
        // totals invariant under conditioning
        assert_eq!(full.total_internal(), depth.total_internal());
        assert_eq!(full.total_internal(), none.total_internal());
    }

    #[test]
    fn root_splits_concentrate_vs_deep_splits() {
        // the paper's §6 observation: low-depth models are sparse/low-entropy,
        // deep models approach uniform. Verify entropy grows with depth.
        let ds = synthetic::airfoil_classification(8);
        let f = Forest::train(&ds, &ForestParams::classification(30), 17);
        let al = ValueAlphabets::collect(&f, &ds).unwrap();
        let m = ForestModels::extract(&f, &al, ModelConditioning::DepthOnly, 1);
        let entropy_at = |depth: u16| -> Option<f64> {
            m.var_names
                .get(&ContextKey { depth, father: 0 })
                .map(|c| crate::coding::entropy::entropy_counts(c))
        };
        let h0 = entropy_at(0).expect("root model");
        let mid = (f.max_depth() / 2) as u16;
        if let Some(hm) = entropy_at(mid) {
            assert!(
                hm >= h0 * 0.8,
                "deep split-name entropy ({hm:.3}) should not be far below root ({h0:.3})"
            );
        }
    }
}
