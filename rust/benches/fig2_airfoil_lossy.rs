//! **Figure 2** — lossy compression of the Airfoil Self Noise regression
//! forest: (upper chart) MSE + compressed size vs fit-quantization bits;
//! (lower chart) MSE + size vs number of subsampled trees at the knee
//! bit-width. The paper's headline: 7-bit fits shrink 340 KB → ~47 KB and
//! 250/1000 trees reach ~11 KB, both without meaningful MSE loss.
//!
//! ```text
//! cargo bench --bench fig2_airfoil_lossy                 # 200 trees
//! cargo bench --bench fig2_airfoil_lossy -- --paper-scale
//! ```
//!
//! The σ²-theory overlay (eq. 7) is printed next to the measured MSE.

use rf_compress::compress::CompressOptions;
use rf_compress::coordinator::Coordinator;
use rf_compress::data::synthetic;
use rf_compress::forest::Fit;
use rf_compress::lossy::{self, theory};
use rf_compress::util::bench::{bench_config, Table};
use rf_compress::util::stats::human_bytes;
use rf_compress::util::Pcg64;

fn main() {
    let cfg = bench_config(200);
    println!("== Figure 2: Airfoil Self Noise lossy compression, {} trees ==", cfg.trees);
    let ds = synthetic::airfoil_regression(cfg.args.get_or("data-seed", 1234));
    let mut rng = Pcg64::new(cfg.seed);
    let tt = ds.train_test_split(0.8, &mut rng);
    let mut coord = if cfg.args.flag("native") {
        Coordinator::native_only()
    } else {
        Coordinator::new()
    };
    let forest = coord.train(&tt.train, cfg.trees, cfg.seed);
    let full_mse = forest.test_error(&tt.test);
    let opts = CompressOptions::default();
    let (cf_full, _) = coord.run_job(&tt.train, &forest, &opts, 0.0).unwrap();
    println!(
        "lossless baseline: test MSE {full_mse:.4}, size {} (paper: 340 KB at 1000 trees)\n",
        human_bytes(cf_full.total_bytes())
    );

    // ---- upper chart: fits quantization ----
    println!("-- upper chart: fit quantization (all {} trees) --", cfg.trees);
    let fit_range = fit_range(&forest);
    let mut t = Table::new(&["bits", "test MSE", "MSE/lossless", "size", "theory ΔMSE (eq.7)"]);
    let bits_list: Vec<u32> = cfg.args.get_list("bits").unwrap_or_else(|| vec![2, 3, 4, 5, 6, 7, 8, 10, 12, 16]);
    for &bits in &bits_list {
        let (qf, _) = lossy::quantize_fits(&forest, bits, lossy::QuantizeMethod::Uniform).unwrap();
        let mse = qf.test_error(&tt.test);
        let (cf, _) = coord.run_job(&tt.train, &qf, &opts, 0.0).unwrap();
        t.row(&[
            bits.to_string(),
            format!("{mse:.4}"),
            format!("{:.3}", mse / full_mse.max(1e-12)),
            human_bytes(cf.total_bytes()),
            format!("{:.2e}", theory::quantization_mse(fit_range, bits)),
        ]);
    }
    t.print();

    // ---- lower chart: tree subsampling at the knee bit-width ----
    let knee_bits: u32 = cfg.args.get_or("knee-bits", 7);
    println!("\n-- lower chart: subsampling ({knee_bits}-bit fits) --");
    let (qf, _) = lossy::quantize_fits(&forest, knee_bits, lossy::QuantizeMethod::Uniform).unwrap();
    // σ² estimate from per-tree mean errors (paper §7 construction)
    let sigma2 = estimate_sigma2(&qf, &tt.test);
    let mut t = Table::new(&["trees |A0|", "test MSE", "MSE/lossless", "size", "σ²/|A0|+σ²/|A| (eq.7)"]);
    let keeps: Vec<usize> = cfg
        .args
        .get_list("keep")
        .unwrap_or_else(|| {
            let n = cfg.trees;
            vec![n, n * 3 / 4, n / 2, n / 4, n / 8, (n / 16).max(2)]
        });
    let mut sizes = Vec::new();
    for &keep in &keeps {
        let sub = lossy::subsample_trees(&qf, keep, cfg.seed ^ 0xa0);
        let mse = sub.test_error(&tt.test);
        let (cf, _) = coord.run_job(&tt.train, &sub, &opts, 0.0).unwrap();
        sizes.push((keep, cf.total_bytes()));
        t.row(&[
            keep.to_string(),
            format!("{mse:.4}"),
            format!("{:.3}", mse / full_mse.max(1e-12)),
            human_bytes(cf.total_bytes()),
            format!("{:.2e}", theory::subsample_distortion_approx(cfg.trees, keep, sigma2)),
        ]);
    }
    t.print();

    // the paper's "linear threads": size ≈ linear in |A0|
    if sizes.len() >= 3 {
        let (k1, s1) = sizes[0];
        let (k2, s2) = *sizes.last().unwrap();
        let per_tree = (s1 - s2) as f64 / (k1 - k2) as f64;
        println!(
            "\nlinearity check: marginal size ≈ {:.0} B/tree (paper: size curves are linear in |A0|)",
            per_tree
        );
    }
    println!(
        "paper endpoint: 250/1000 trees at 7 bits → 11 KB with no significant MSE change"
    );
}

fn fit_range(forest: &rf_compress::forest::Forest) -> f64 {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for t in &forest.trees {
        for n in &t.nodes {
            if let Fit::Regression(v) = n.fit {
                lo = lo.min(v);
                hi = hi.max(v);
            }
        }
    }
    (hi - lo).max(0.0)
}

/// σ² from per-tree mean deviation against the ensemble (paper §7).
fn estimate_sigma2(forest: &rf_compress::forest::Forest, test: &rf_compress::data::Dataset) -> f64 {
    let n = test.num_rows();
    let ens: Vec<f64> = (0..n).map(|r| forest.predict_regression(test, r)).collect();
    let per_tree: Vec<f64> = forest
        .trees
        .iter()
        .map(|t| {
            let mut acc = 0.0;
            for r in 0..n {
                match t.predict_row(test, r) {
                    Fit::Regression(v) => acc += v - ens[r],
                    _ => unreachable!(),
                }
            }
            acc / n as f64
        })
        .collect();
    theory::estimate_sigma2(&per_tree)
}
