//! **Table 1** — the Liberty Mutual classification case study (paper §6):
//! per-component compressed sizes, light representation vs Algorithm 1.
//!
//! ```text
//! cargo bench --bench table1_liberty            # 60 trees (scaled)
//! cargo bench --bench table1_liberty -- --trees 200
//! cargo bench --bench table1_liberty -- --paper-scale   # 1000 trees
//! ```
//!
//! Absolute MBs differ from the paper (synthetic Liberty stand-in, §7 of
//! DESIGN.md); the reproduced quantities are the column *shares* (splits
//! dominating light, fits tiny after binarization) and the ours≪light≪
//! standard ordering, which sharpens as tree count grows.

use rf_compress::baseline;
use rf_compress::compress::CompressOptions;
use rf_compress::coordinator::Coordinator;
use rf_compress::data::synthetic;
use rf_compress::util::bench::{bench_config, Table};
use rf_compress::util::stats::human_bytes;

fn main() {
    let cfg = bench_config(60);
    println!("== Table 1: Liberty* classification, {} trees ==", cfg.trees);

    let ds = synthetic::liberty_classification(cfg.args.get_or("data-seed", 1234));
    let mut coord = if cfg.args.flag("native") {
        Coordinator::native_only()
    } else {
        Coordinator::new()
    };
    println!("engine: {}", coord.engine_name());
    let t0 = std::time::Instant::now();
    let forest = coord.train(&ds, cfg.trees, cfg.seed);
    let train_s = t0.elapsed().as_secs_f64();
    println!(
        "forest: {} trees, {} nodes, mean depth {:.1} (train {:.1}s)",
        forest.num_trees(),
        forest.total_nodes(),
        forest.mean_depth(),
        train_s
    );

    // light representation per-component (gzip per component, like the
    // paper's light row)
    let (light_raw, light_sections) = baseline::light_representation(&forest);
    let light_gz = baseline::gzip::gzip(&light_raw).len() as u64;
    // paper accounting (observation-rank split coding) unless opted out
    let opts = CompressOptions {
        dataset_indexed_splits: !cfg.args.flag("self-contained"),
        ..Default::default()
    };
    let (cf, report) = coord.run_job(&ds, &forest, &opts, train_s).expect("compression");
    let restored = if opts.dataset_indexed_splits {
        cf.decompress_with_dataset(&ds).unwrap()
    } else {
        cf.decompress().unwrap()
    };
    assert!(restored.identical(&forest), "losslessness");

    let ours = cf.sizes.paper_columns();
    let mut t = Table::new(&["method", "tree struct", "var names", "split values", "fits", "dict", "total"]);
    t.row(&[
        "light comp. (pre-gzip)".into(),
        human_bytes(light_sections.structure),
        human_bytes(light_sections.var_names),
        human_bytes(light_sections.split_values),
        human_bytes(light_sections.fits),
        "-".into(),
        human_bytes(
            light_sections.structure
                + light_sections.var_names
                + light_sections.split_values
                + light_sections.fits,
        ),
    ]);
    t.row(&[
        "light comp. (gzip)".into(),
        "".into(),
        "".into(),
        "".into(),
        "".into(),
        "-".into(),
        human_bytes(light_gz),
    ]);
    t.row(&[
        "our method".into(),
        human_bytes(ours.structure),
        human_bytes(ours.var_names),
        human_bytes(ours.split_values),
        human_bytes(ours.fits),
        human_bytes(ours.dict),
        human_bytes(ours.total()),
    ]);
    t.print();

    println!("\npaper (1000 trees, real Liberty): light 96.5 MB → ours 12.43 MB (1:5.2 vs light, 1:40 vs standard)");
    println!(
        "measured ({} trees, synthetic Liberty): standard {} → light {} → ours {}  (1:{:.1} vs standard, 1:{:.1} vs light)",
        cfg.trees,
        human_bytes(report.standard_bytes),
        human_bytes(light_gz),
        human_bytes(report.ours_bytes),
        report.standard_ratio(),
        light_gz as f64 / report.ours_bytes as f64,
    );
    println!(
        "clusters chosen (§6 predicts 2–3 at 64-bit α): {:?}",
        report.cluster_ks
    );
    println!(
        "timing: compress {:.2}s ({} xla / {} native Lloyd steps)",
        report.compress_s, report.xla_steps, report.native_steps
    );
}
