//! §Perf hot paths:
//!
//! * cluster-step: native vs XLA engine at each artifact bucket size
//! * compression throughput (trees/s) end to end
//! * prediction latency: compressed prefix-decode vs decompressed forest
//! * serving hot path: single-row latency (p50/p99), batch throughput of
//!   the PR-1 re-decode baseline vs the flat-tree engine (cold and with a
//!   warm plan cache), worker scaling on both parallelism axes, and the
//!   serial-vs-pipelined client tail-latency comparison over a mixed
//!   hot/cold model set (one connection, `PIPE` out-of-order replies vs
//!   head-of-line-blocked `PREDICT`); emits the machine-readable
//!   `BENCH_serve.json` tracked across PRs (and gated by
//!   `repro bench-gate` in CI)
//! * tiered-store spill path: mmap-backed reload (map + header parse) vs a
//!   cold full-read parse, p50/p99, plus the end-to-end spill→reload round
//!   trip through the store; emits `BENCH_spill.json`
//! * model packs: bytes/model and member-reload p50/p99 of one `RFPK`
//!   archive vs per-file spill at N × ≤4 KiB models (the ROADMAP's
//!   page-granularity-waste scenario), after a bit-identical extraction
//!   gate over every member; plus generation-chain read overhead at
//!   depth 1/2/4 and after a merge compaction; emits `BENCH_pack.json`
//! * shard router: per-request overhead vs a direct backend (p50/p99) and
//!   a failover burst with one of three backends severed mid-volley via
//!   the chaos proxy, gated on exactly-once resolution; emits
//!   `BENCH_route.json`
//! * codec microbenches: Huffman encode/decode, arith, LZSS
//! * observability overhead: the traced store path (phase spans feeding
//!   the `METRICS`/`SLOW` surface) vs the untraced fast path on a warm
//!   flat-plan batch, gated at ≤ 5% throughput overhead; emits
//!   `BENCH_obs.json`
//!
//! Run: `cargo bench --bench hotpath`
//! (add `-- cluster|compress|predict|serve|spill|pack|route|codec|obs`;
//! `-- serve --quick`, `-- spill --quick`, `-- pack --quick`, and
//! `-- obs --quick` are the CI smoke configurations: tiny forests / member
//! counts, short timing budgets; `-- spill --spill-bytes B` caps the disk
//! tier and `-- pack --members N` sets the cohort size)

use rf_compress::cluster::kmeans::{LloydEngine, NativeEngine};
use rf_compress::compress::{CompressOptions, CompressedForest, CompressedPredictor, PlanCache};
use rf_compress::data::synthetic;
use rf_compress::forest::{Forest, ForestParams};
use rf_compress::runtime::XlaRuntime;
use rf_compress::util::bench::{bench_config, time_it, Table, Timing};
use rf_compress::util::Pcg64;
use std::sync::Arc;

fn main() {
    let cfg = bench_config(40);
    let which = cfg.args.positional(0).map(|s| s.to_string());
    let run = |name: &str| which.as_deref().map_or(true, |w| w == name);
    if run("cluster") {
        bench_cluster();
    }
    if run("compress") {
        bench_compress(&cfg);
    }
    if run("predict") {
        bench_predict(&cfg);
    }
    if run("serve") {
        bench_serve(&cfg);
    }
    if run("spill") {
        bench_spill(&cfg);
    }
    if run("pack") {
        bench_pack(&cfg);
    }
    if run("route") {
        bench_route(&cfg);
    }
    if run("codec") {
        bench_codec();
    }
    if run("obs") {
        bench_obs(&cfg);
    }
}

/// Observability overhead: the traced store path
/// (`predict_batch_traced`, which times tier probes and execute windows
/// and feeds the request histogram) vs the untraced `predict_batch` fast
/// path, on a warm flat-plan batch — plus the traced path with recording
/// disabled (`Obs::set_enabled(false)`, the hub-off leg). Gates the traced
/// path at ≥ 95% of untraced throughput and emits `BENCH_obs.json`.
fn bench_obs(cfg: &rf_compress::util::bench::BenchConfig) {
    use rf_compress::coordinator::store::{ModelStore, ObsValue};
    use rf_compress::obs::BatchTrace;

    println!("== observability overhead: traced vs untraced warm path ==");
    let quick = cfg.args.flag("quick");
    let budget = if quick { 0.05 } else { 0.5 };
    let ds = synthetic::airfoil_classification(1234);
    let n_trees = if quick { cfg.trees.min(16).max(4) } else { cfg.trees.max(50) };
    let forest = Forest::train(&ds, &ForestParams::classification(n_trees), cfg.seed);
    let cf = CompressedForest::compress(&forest, &ds, &CompressOptions::default()).unwrap();
    let store = ModelStore::new().slow_threshold_us(0).trace_ring(64);
    store.insert("m", &cf).unwrap();
    let rows: Vec<Vec<ObsValue>> = (0..ds.num_rows().min(64))
        .map(|r| {
            ds.features
                .iter()
                .map(|f| match &f.column {
                    rf_compress::data::Column::Numeric(v) => ObsValue::Num(v[r]),
                    rf_compress::data::Column::Categorical { values, .. } => {
                        ObsValue::Cat(values[r])
                    }
                })
                .collect()
        })
        .collect();
    let n_rows = rows.len();

    // correctness gate: traced and untraced paths answer identically
    let plain_out = store.predict_batch("m", &rows).unwrap(); // also warms the plan cache
    let mut gate_trace = BatchTrace::default();
    assert_eq!(
        store.predict_batch_traced("m", &rows, &mut gate_trace).unwrap(),
        plain_out,
        "traced path diverges from the fast path"
    );
    assert!(gate_trace.execute_us > 0 || n_rows == 0, "the trace must time the execute window");

    // two interleaved passes per leg; keep each leg's best median so one
    // scheduler hiccup cannot fail the overhead gate
    let mut t_plain_best = f64::MAX;
    let mut t_traced_best = f64::MAX;
    let mut t_off_best = f64::MAX;
    for _ in 0..2 {
        let t_plain = time_it(budget, 3, || {
            store.predict_batch("m", &rows).unwrap();
        });
        let t_traced = time_it(budget, 3, || {
            let mut trace = BatchTrace::default();
            store.predict_batch_traced("m", &rows, &mut trace).unwrap();
        });
        store.obs().set_enabled(false);
        let t_off = time_it(budget, 3, || {
            let mut trace = BatchTrace::default();
            store.predict_batch_traced("m", &rows, &mut trace).unwrap();
        });
        store.obs().set_enabled(true);
        t_plain_best = t_plain_best.min(t_plain.median);
        t_traced_best = t_traced_best.min(t_traced.median);
        t_off_best = t_off_best.min(t_off.median);
    }
    let rps = |median: f64| n_rows as f64 / median.max(1e-12);
    let overhead = t_traced_best / t_plain_best.max(1e-12) - 1.0;
    let mut t = Table::new(&["store path", "batch median", "rows/s", "vs untraced"]);
    t.row(&[
        "untraced predict_batch".into(),
        format!("{:.1} µs", t_plain_best * 1e6),
        format!("{:.0}", rps(t_plain_best)),
        "1.00x".into(),
    ]);
    t.row(&[
        "traced, recording on".into(),
        format!("{:.1} µs", t_traced_best * 1e6),
        format!("{:.0}", rps(t_traced_best)),
        format!("{:.2}x", t_plain_best / t_traced_best),
    ]);
    t.row(&[
        "traced, recording off".into(),
        format!("{:.1} µs", t_off_best * 1e6),
        format!("{:.0}", rps(t_off_best)),
        format!("{:.2}x", t_plain_best / t_off_best),
    ]);
    t.print();
    println!("tracing overhead on the warm path: {:.1}%", overhead * 100.0);
    assert!(
        overhead <= 0.05,
        "tracing costs {:.1}% of warm-path throughput (gate: 5%)",
        overhead * 100.0
    );

    let json = [
        "{".to_string(),
        "  \"bench\": \"hotpath obs\",".to_string(),
        format!("  \"trees\": {n_trees},"),
        format!("  \"batch_rows\": {n_rows},"),
        format!("  \"untraced_rows_per_s\": {:.0},", rps(t_plain_best)),
        format!("  \"traced_rows_per_s\": {:.0},", rps(t_traced_best)),
        format!("  \"recording_off_rows_per_s\": {:.0},", rps(t_off_best)),
        format!("  \"overhead_pct\": {:.2}", overhead * 100.0),
        "}".to_string(),
    ]
    .join("\n")
        + "\n";
    match std::fs::write("BENCH_obs.json", &json) {
        Ok(()) => println!("wrote BENCH_obs.json"),
        Err(e) => eprintln!("could not write BENCH_obs.json: {e}"),
    }
    println!();
}

/// Router hot path: per-request overhead of the shard-routing coordinator
/// vs a direct backend (p50/p99 over serial round trips), then a failover
/// burst — a pipelined volley with one of three backends severed mid-burst
/// (via the chaos proxy) — asserting exactly-once resolution before timing
/// anything. Emits `BENCH_route.json`.
fn bench_route(cfg: &rf_compress::util::bench::BenchConfig) {
    use rf_compress::coordinator::health::HealthPolicy;
    use rf_compress::coordinator::router::{Router, RouterConfig};
    use rf_compress::coordinator::server::{values_to_wire, Client, PipeReply, Server};
    use rf_compress::coordinator::store::{ModelStore, ObsValue};
    use rf_compress::coordinator::Coordinator;
    use rf_compress::data::Column;
    use std::time::Duration;

    println!("== shard router: overhead vs direct, failover burst ==");
    let quick = cfg.args.flag("quick");
    let n_req = if quick { 48 } else { 200 };
    let n_trees = if quick { cfg.trees.min(16).max(4) } else { cfg.trees.max(40) };
    let ds = synthetic::iris(cfg.seed);
    let mut coord = Coordinator::native_only();
    let models = ["alpha", "beta", "gamma", "delta"];
    let forests: Vec<CompressedForest> = models
        .iter()
        .enumerate()
        .map(|(i, _)| {
            coord
                .train_and_compress(&ds, n_trees, cfg.seed + i as u64, &CompressOptions::default())
                .unwrap()
                .1
        })
        .collect();
    // three identical backends: any of them doubles as the direct baseline
    let backends: Vec<Server> = (0..3)
        .map(|_| {
            let store = Arc::new(ModelStore::new());
            for (name, cf) in models.iter().zip(&forests) {
                store.insert(name, cf).unwrap();
            }
            Server::start(store, 0).unwrap()
        })
        .collect();
    let proxies: Vec<rf_compress::testing::chaos::ChaosProxy> =
        backends.iter().map(|b| rf_compress::testing::chaos::ChaosProxy::start(b.addr()).unwrap()).collect();
    let addrs: Vec<std::net::SocketAddr> = proxies.iter().map(|p| p.addr()).collect();
    let router = Router::start(
        &addrs,
        0,
        RouterConfig {
            replication: 2,
            hot_refresh: 8,
            request_timeout: Duration::from_secs(2),
            backoff_base: Duration::from_millis(2),
            health: HealthPolicy {
                eject_after: 2,
                eject_cooldown: Duration::from_millis(200),
                probe_interval: Duration::from_millis(100),
                ..HealthPolicy::default()
            },
            ..RouterConfig::default()
        },
    )
    .unwrap();

    let row0: Vec<ObsValue> = ds
        .features
        .iter()
        .map(|f| match &f.column {
            Column::Numeric(v) => ObsValue::Num(v[0]),
            Column::Categorical { values, .. } => ObsValue::Cat(values[0]),
        })
        .collect();
    let wire = values_to_wire(&row0);
    let quantile = rf_compress::util::stats::quantile;

    // correctness gate before any timing: routed == direct, bit-identical
    let mut routed = Client::connect(router.addr()).unwrap();
    routed.set_deadlines(Some(Duration::from_secs(30)), Some(Duration::from_secs(30))).unwrap();
    let mut direct = Client::connect(backends[0].addr()).unwrap();
    for model in &models {
        let a = routed.request(&format!("PREDICT {model} {wire}")).unwrap();
        let b = direct.request(&format!("PREDICT {model} {wire}")).unwrap();
        assert_eq!(a, b, "routed {model} diverged from the direct backend");
    }
    // warm the hot set so every key routes with the full replica set
    for _ in 0..2 {
        for model in &models {
            let _ = routed.request(&format!("PREDICT {model} {wire}")).unwrap();
        }
    }

    let serial_lat = |client: &mut Client, label: &str| -> Vec<f64> {
        let mut us = Vec::with_capacity(n_req);
        for i in 0..n_req {
            let model = models[i % models.len()];
            let t0 = std::time::Instant::now();
            let reply = client.request(&format!("PREDICT {model} {wire}")).unwrap();
            us.push(t0.elapsed().as_secs_f64() * 1e6);
            assert!(reply.starts_with("OK"), "{label} request {i}: {reply}");
        }
        us
    };
    let direct_us = serial_lat(&mut direct, "direct");
    let routed_us = serial_lat(&mut routed, "routed");
    let (direct_p50, direct_p99) = (quantile(&direct_us, 0.5), quantile(&direct_us, 0.99));
    let (routed_p50, routed_p99) = (quantile(&routed_us, 0.5), quantile(&routed_us, 0.99));

    // failover burst: pipelined volley, one backend severed a third in;
    // every id must resolve exactly once (success or typed error)
    let epoch = std::time::Instant::now();
    for i in 0..n_req {
        let model = models[i % models.len()];
        routed.pipe_predict(i as u64, model, &wire).unwrap();
        if i == n_req / 3 {
            proxies[0].sever();
        }
    }
    let replies = routed.collect_pipelined(n_req).unwrap();
    let burst_secs = epoch.elapsed().as_secs_f64();
    let mut seen = vec![false; n_req];
    let mut failed = 0usize;
    for r in &replies {
        let id = r.id().expect("router replies carry ids") as usize;
        assert!(!seen[id], "id {id} answered twice during failover");
        seen[id] = true;
        if let PipeReply::Err { message, .. } = r {
            assert!(
                message.starts_with("unavailable") || message.starts_with("upstream"),
                "untyped failure under partition: {message:?}"
            );
            failed += 1;
        }
    }
    assert!(seen.iter().all(|&s| s), "some burst ids never resolved");
    proxies[0].restore();
    let stats = router.stats();

    let mut t = Table::new(&["path", "p50", "p99", "p99 overhead"]);
    t.row(&[
        "direct backend".into(),
        format!("{direct_p50:.0} µs"),
        format!("{direct_p99:.0} µs"),
        "1.00x".into(),
    ]);
    t.row(&[
        "via router".into(),
        format!("{routed_p50:.0} µs"),
        format!("{routed_p99:.0} µs"),
        format!("{:.2}x", routed_p99 / direct_p99.max(1e-9)),
    ]);
    t.print();
    println!(
        "failover burst: {n_req} requests, 1/3 in when severed — {:.1} ms total, \
         {failed} typed failures, retries={} failovers={} ejections={}",
        burst_secs * 1e3,
        stats.retries,
        stats.failovers,
        stats.ejections
    );

    let json = [
        "{".to_string(),
        "  \"bench\": \"hotpath route\",".to_string(),
        format!("  \"trees\": {n_trees},"),
        format!("  \"requests\": {n_req},"),
        format!(
            "  \"direct_us\": {{\"p50\": {direct_p50:.2}, \"p99\": {direct_p99:.2}}},"
        ),
        format!(
            "  \"routed_us\": {{\"p50\": {routed_p50:.2}, \"p99\": {routed_p99:.2}}},"
        ),
        format!(
            "  \"router_overhead\": {{\"p50\": {:.3}, \"p99\": {:.3}}},",
            routed_p50 / direct_p50.max(1e-9),
            routed_p99 / direct_p99.max(1e-9)
        ),
        format!(
            "  \"failover_burst\": {{\"requests\": {n_req}, \"total_ms\": {:.2}, \
             \"typed_failures\": {failed}, \"retries\": {}, \"failovers\": {}, \
             \"ejections\": {}}}",
            burst_secs * 1e3,
            stats.retries,
            stats.failovers,
            stats.ejections
        ),
        "}".to_string(),
    ]
    .join("\n")
        + "\n";
    match std::fs::write("BENCH_route.json", &json) {
        Ok(()) => println!("wrote BENCH_route.json"),
        Err(e) => println!("could not write BENCH_route.json: {e}"),
    }
    router.stop();
    for p in &proxies {
        p.stop();
    }
    for b in &backends {
        b.stop();
    }
}

fn random_problem(seed: u64, m: usize, b: usize, k: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let mut rng = Pcg64::new(seed);
    let mut p = vec![0.0; m * b];
    for i in 0..m {
        let row = &mut p[i * b..(i + 1) * b];
        let mut total = 0.0;
        for x in row.iter_mut() {
            *x = rng.gen_f64().powi(3);
            total += *x;
        }
        for x in row.iter_mut() {
            *x /= total;
        }
    }
    let w: Vec<f64> = (0..m).map(|_| (1 + rng.gen_range(999)) as f64).collect();
    let mut q = vec![0.0; k * b];
    for i in 0..k {
        let row = &mut q[i * b..(i + 1) * b];
        let mut total = 0.0;
        for x in row.iter_mut() {
            *x = rng.gen_f64() + 1e-3;
            total += *x;
        }
        for x in row.iter_mut() {
            *x /= total;
        }
    }
    (p, w, q)
}

fn bench_cluster() {
    println!("== cluster step: native vs XLA artifact ==");
    let rt = XlaRuntime::load_default().ok();
    if rt.is_none() {
        println!("(artifacts not built; native only — run `make artifacts`)");
    }
    let mut t = Table::new(&["problem (M×B×K)", "native", "xla", "xla/native"]);
    for &(m, b, k) in &[(128usize, 256usize, 8usize), (512, 256, 8), (512, 1024, 12), (2048, 2048, 12)] {
        let (p, w, q) = random_problem(1, m, b, k);
        let mut native = NativeEngine;
        let tn = time_it(0.4, 3, || {
            native.step(&p, &w, &q, m, b, k).unwrap();
        });
        let (tx_s, ratio) = if let Some(rt) = &rt {
            if rt.fits(m, b, k) {
                let tx = time_it(0.4, 3, || {
                    rt.try_step(&p, &w, &q, m, b, k).unwrap().unwrap();
                });
                (format!("{tx}"), format!("{:.2}x", tx.median / tn.median))
            } else {
                ("no bucket".into(), "-".into())
            }
        } else {
            ("-".into(), "-".into())
        };
        t.row(&[format!("{m}×{b}×{k}"), format!("{tn}"), tx_s, ratio]);
    }
    t.print();
    println!();
}

fn bench_compress(cfg: &rf_compress::util::bench::BenchConfig) {
    println!("== end-to-end compression throughput ==");
    let mut t = Table::new(&["dataset", "trees", "nodes", "compress", "trees/s", "Mnodes/s"]);
    for (name, ds) in [
        ("wages", synthetic::wages(1234)),
        ("airfoil*", synthetic::airfoil_classification(1234)),
        ("naval*", synthetic::naval_classification(1234)),
    ] {
        let n = cfg.trees;
        let params = ForestParams::classification(n);
        let forest = Forest::train(&ds, &params, cfg.seed);
        let opts = CompressOptions::default();
        let tc = time_it(1.0, 3, || {
            CompressedForest::compress(&forest, &ds, &opts).unwrap();
        });
        t.row(&[
            name.into(),
            n.to_string(),
            forest.total_nodes().to_string(),
            format!("{tc}"),
            format!("{:.0}", tc.per_sec(n as f64)),
            format!("{:.2}", tc.per_sec(forest.total_nodes() as f64) / 1e6),
        ]);
    }
    t.print();
    println!();
}

fn bench_predict(cfg: &rf_compress::util::bench::BenchConfig) {
    println!("== prediction latency: compressed vs decompressed ==");
    let ds = synthetic::airfoil_classification(1234);
    let forest = Forest::train(&ds, &ForestParams::classification(cfg.trees), cfg.seed);
    let cf = CompressedForest::compress(&forest, &ds, &CompressOptions::default()).unwrap();
    let pc = cf.parse().unwrap();
    let predictor = CompressedPredictor::new(pc).unwrap();
    let decompressed = cf.decompress().unwrap();

    let rows: Vec<usize> = (0..ds.num_rows()).step_by(37).collect();
    let mut i = 0usize;
    let t_comp = time_it(1.0, 5, || {
        let row = rows[i % rows.len()];
        i += 1;
        predictor.predict_row(&ds, row).unwrap();
    });
    let mut j = 0usize;
    let t_full = time_it(1.0, 5, || {
        let row = rows[j % rows.len()];
        j += 1;
        decompressed.predict_class(&ds, row);
    });
    let t_batch = time_it(1.0, 3, || {
        predictor.predict_all(&ds).unwrap();
    });
    let mut t = Table::new(&["mode", "latency/query", "notes"]);
    t.row(&["decompressed forest".into(), format!("{t_full}"), "full tree walk".into()]);
    t.row(&[
        "compressed, per-row".into(),
        format!("{t_comp}"),
        format!("{:.0}x full-walk cost (prefix decode)", t_comp.median / t_full.median),
    ]);
    t.row(&[
        "compressed, batch".into(),
        format!("{:.2} µs/row", t_batch.median * 1e6 / ds.num_rows() as f64),
        "per-tree decode amortized over all rows".into(),
    ]);
    t.print();
    println!(
        "memory: container {} vs decompressed forest ~{} nodes\n",
        rf_compress::util::stats::human_bytes(cf.total_bytes()),
        decompressed.total_nodes()
    );
}

fn bench_serve(cfg: &rf_compress::util::bench::BenchConfig) {
    println!("== serving hot path: flat-tree batch engine vs prefix decode ==");
    // --quick shrinks the forest and timing budgets for the CI smoke stage
    let quick = cfg.args.flag("quick");
    let budget = if quick { 0.05 } else { 1.0 };
    let ds = synthetic::airfoil_classification(1234);
    let n_trees = if quick { cfg.trees.min(24).max(4) } else { cfg.trees.max(100) };
    let forest = Forest::train(&ds, &ForestParams::classification(n_trees), cfg.seed);
    let cf = CompressedForest::compress(&forest, &ds, &CompressOptions::default()).unwrap();
    let n_rows = ds.num_rows();

    // parse cost (zero-copy: spans into the shared Arc buffer, no section
    // allocation) — this is the per-insert cost of the model store
    let t_parse = time_it(budget.min(0.5), 3, || {
        cf.parse().unwrap();
    });
    println!(
        "container parse ({}): {t_parse}",
        rf_compress::util::stats::human_bytes(cf.total_bytes())
    );

    let predictor = CompressedPredictor::new(cf.parse().unwrap()).unwrap();

    // correctness gate (the CI smoke stage trips on any divergence): the
    // flat engine must agree with the re-decode baseline, the original
    // forest, and itself across worker counts
    let flat_out = predictor.predict_all(&ds).unwrap();
    assert_eq!(
        flat_out,
        predictor.predict_all_baseline(&ds).unwrap(),
        "flat engine diverges from the re-decode baseline"
    );
    assert_eq!(flat_out, forest.predict_all(&ds), "flat engine diverges from the forest");
    for w in [2usize, 8] {
        assert_eq!(
            predictor.predict_all_workers(&ds, w).unwrap(),
            flat_out,
            "flat engine diverges at {w} workers"
        );
    }

    // single-row latency (the subscriber-device path)
    let rows: Vec<usize> = (0..n_rows).step_by(37).collect();
    let mut i = 0usize;
    let t_row = time_it(budget, 5, || {
        let row = rows[i % rows.len()];
        i += 1;
        predictor.predict_row(&ds, row).unwrap();
    });
    println!("single-row latency ({n_trees} trees): {t_row}");

    // batch throughput: PR-1 per-batch re-decode baseline vs the flat
    // engine cold (decode per batch) vs warm (plan cache primed)
    let t_base = time_it(budget, 3, || {
        predictor.predict_all_baseline(&ds).unwrap();
    });
    let t_cold = time_it(budget, 3, || {
        predictor.predict_all(&ds).unwrap();
    });
    let cache = Arc::new(PlanCache::new(256 << 20));
    let warm_predictor = CompressedPredictor::new(cf.parse().unwrap())
        .unwrap()
        .with_plan_cache(cache.clone());
    warm_predictor.predict_all(&ds).unwrap(); // prime the cache
    let t_warm = time_it(budget, 3, || {
        warm_predictor.predict_all(&ds).unwrap();
    });
    let rps = |t: &Timing| t.per_sec(n_rows as f64);
    let mut t = Table::new(&["batch path", "time", "rows/s", "vs baseline"]);
    t.row(&[
        "re-decode baseline (PR 1)".into(),
        format!("{t_base}"),
        format!("{:.0}", rps(&t_base)),
        "1.00x".into(),
    ]);
    t.row(&[
        "flat engine, cold".into(),
        format!("{t_cold}"),
        format!("{:.0}", rps(&t_cold)),
        format!("{:.2}x", t_base.median / t_cold.median),
    ]);
    t.row(&[
        "flat engine, warm plans".into(),
        format!("{t_warm}"),
        format!("{:.0}", rps(&t_warm)),
        format!("{:.2}x", t_base.median / t_warm.median),
    ]);
    t.print();

    // worker scaling on the warm engine (tree axis: n_trees >= 2*workers)
    let mut scaling = Vec::new();
    let mut t = Table::new(&["workers", "batch predict_all", "rows/s", "speedup"]);
    let mut base = None::<f64>;
    for &w in &[1usize, 2, 4, 8] {
        let tb = time_it(budget, 3, || {
            warm_predictor.predict_all_workers(&ds, w).unwrap();
        });
        let b = *base.get_or_insert(tb.median);
        scaling.push((w, rps(&tb)));
        t.row(&[
            w.to_string(),
            format!("{tb}"),
            format!("{:.0}", rps(&tb)),
            format!("{:.2}x", b / tb.median),
        ]);
    }
    t.print();

    // row-axis scaling: a few-tree forest on the same wide batch (trees
    // alone cannot keep the workers busy; rows must)
    let small_forest = Forest::train(&ds, &ForestParams::classification(4), cfg.seed ^ 1);
    let small_cf =
        CompressedForest::compress(&small_forest, &ds, &CompressOptions::default()).unwrap();
    let small = CompressedPredictor::new(small_cf.parse().unwrap())
        .unwrap()
        .with_plan_cache(cache.clone());
    small.predict_all(&ds).unwrap(); // prime
    let t_small_1 = time_it(budget, 3, || {
        small.predict_all_workers(&ds, 1).unwrap();
    });
    let t_small_8 = time_it(budget, 3, || {
        small.predict_all_workers(&ds, 8).unwrap();
    });
    println!(
        "row-axis (4-tree forest, {n_rows} rows): 1 worker {:.0} rows/s, \
         8 workers {:.0} rows/s",
        rps(&t_small_1),
        rps(&t_small_8)
    );

    // pipelined vs serial tail latency over TCP (mixed hot/cold models)
    let pipeline = bench_pipeline(&ds, &cf, &small_cf, quick);

    let ps = cache.stats();
    write_serve_json(
        n_trees,
        n_rows,
        &t_row,
        rps(&t_base),
        rps(&t_cold),
        rps(&t_warm),
        &scaling,
        (rps(&t_small_1), rps(&t_small_8)),
        (ps.hits, ps.misses, ps.resident_bytes),
        &pipeline,
    );
    println!();
}

/// Serial-vs-pipelined client comparison: one connection fires a flash
/// crowd of requests over a **mixed hot/cold model set** — most target a
/// tiny resident model, every eighth targets a big model that was just
/// spilled to disk (so answering it pays the reload). Latency is measured
/// per request from the common issue epoch (the moment the crowd arrives),
/// which is what a user behind the connection experiences: the serial
/// client pays head-of-line blocking — every request waits for all earlier
/// replies, each with its own batch window — while the pipelined client
/// overlaps the cold reloads with every hot answer and collects replies
/// out of order.
struct PipelineBench {
    requests: usize,
    /// Pooled median over all passes.
    serial_p50_us: f64,
    /// Median of the per-pass p99s (robust to one stalled pass).
    serial_p99_us: f64,
    /// Pooled median over all passes.
    pipe_p50_us: f64,
    /// Median of the per-pass p99s (robust to one stalled pass).
    pipe_p99_us: f64,
}

fn bench_pipeline(
    ds: &rf_compress::data::Dataset,
    cold_cf: &CompressedForest,
    hot_cf: &CompressedForest,
    quick: bool,
) -> PipelineBench {
    use rf_compress::coordinator::server::{values_to_wire, Client, PipeReply, Server};
    use rf_compress::coordinator::store::{ModelStore, ObsValue};
    use rf_compress::data::Column;

    println!("== pipelined vs serial tail latency (mixed hot/cold models) ==");
    let n_req = if quick { 32 } else { 64 };
    let passes = if quick { 3 } else { 5 };
    const COLD_MODELS: usize = 4;
    let dir = std::env::temp_dir().join(format!("rfc-pipe-bench-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let store = std::sync::Arc::new(ModelStore::new().spill_dir(&dir));
    store.insert("hot", hot_cf).unwrap();
    for i in 0..COLD_MODELS {
        store.insert(&format!("cold-{i}"), cold_cf).unwrap();
    }
    let server = Server::start(store.clone(), 0).unwrap();

    let row0: Vec<ObsValue> = ds
        .features
        .iter()
        .map(|f| match &f.column {
            Column::Numeric(v) => ObsValue::Num(v[0]),
            Column::Categorical { values, .. } => ObsValue::Cat(values[0]),
        })
        .collect();
    let wire = values_to_wire(&row0);
    // request i targets a cold (just-spilled, big) model every 8th slot and
    // the hot resident model otherwise
    let target = |i: usize| {
        if i % 8 == 0 {
            format!("cold-{}", (i / 8) % COLD_MODELS)
        } else {
            "hot".to_string()
        }
    };
    // warm the hot model once so both clients measure steady-state heat
    let mut warm = Client::connect(server.addr()).unwrap();
    let reply = warm.request(&format!("PREDICT hot {wire}")).unwrap();
    assert!(reply.starts_with("OK"), "{reply}");

    let spill_all_cold = || {
        for i in 0..COLD_MODELS {
            assert!(
                store.spill(&format!("cold-{i}")).unwrap(),
                "cold model must spill between passes"
            );
        }
    };
    let quantile = rf_compress::util::stats::quantile;
    let mut serial_us: Vec<f64> = Vec::with_capacity(n_req * passes);
    let mut pipe_us: Vec<f64> = Vec::with_capacity(n_req * passes);
    // per-pass p99s: the headline tail metric is the MEDIAN of these, so a
    // single scheduler stall in one pass (a pooled p99 is effectively the
    // max sample) cannot flip the serial-vs-pipelined comparison in CI
    let mut serial_pass_p99: Vec<f64> = Vec::with_capacity(passes);
    let mut pipe_pass_p99: Vec<f64> = Vec::with_capacity(passes);
    for _ in 0..passes {
        // serial: each request waits for the previous reply (head-of-line)
        spill_all_cold();
        let mut client = Client::connect(server.addr()).unwrap();
        let epoch = std::time::Instant::now();
        let mut pass: Vec<f64> = Vec::with_capacity(n_req);
        for i in 0..n_req {
            let reply = client.request(&format!("PREDICT {} {wire}", target(i))).unwrap();
            assert!(reply.starts_with("OK"), "serial request {i}: {reply}");
            pass.push(epoch.elapsed().as_secs_f64() * 1e6);
        }
        serial_pass_p99.push(quantile(&pass, 0.99));
        serial_us.extend(pass);
        // pipelined: issue the whole crowd, collect replies as they arrive
        spill_all_cold();
        let mut client = Client::connect(server.addr()).unwrap();
        let epoch = std::time::Instant::now();
        for i in 0..n_req {
            client.pipe_predict(i as u64, &target(i), &wire).unwrap();
        }
        let mut seen = vec![false; n_req];
        let mut pass: Vec<f64> = Vec::with_capacity(n_req);
        for _ in 0..n_req {
            let reply = client.recv_pipelined().unwrap();
            pass.push(epoch.elapsed().as_secs_f64() * 1e6);
            match reply {
                PipeReply::Ok { id, .. } => seen[id as usize] = true,
                PipeReply::Err { id, message } => {
                    panic!("pipelined request {id:?} failed: {message}")
                }
            }
        }
        assert!(seen.iter().all(|&s| s), "every id answered exactly once");
        pipe_pass_p99.push(quantile(&pass, 0.99));
        pipe_us.extend(pass);
    }
    let out = PipelineBench {
        requests: n_req,
        serial_p50_us: quantile(&serial_us, 0.5),
        serial_p99_us: quantile(&serial_pass_p99, 0.5),
        pipe_p50_us: quantile(&pipe_us, 0.5),
        pipe_p99_us: quantile(&pipe_pass_p99, 0.5),
    };
    let mut t = Table::new(&["client", "p50", "p99", "p99 vs serial"]);
    t.row(&[
        "serial PREDICT (in order)".into(),
        format!("{:.0} µs", out.serial_p50_us),
        format!("{:.0} µs", out.serial_p99_us),
        "1.00x".into(),
    ]);
    t.row(&[
        "pipelined PIPE (out of order)".into(),
        format!("{:.0} µs", out.pipe_p50_us),
        format!("{:.0} µs", out.pipe_p99_us),
        format!("{:.2}x", out.serial_p99_us / out.pipe_p99_us.max(1e-9)),
    ]);
    t.print();
    // the acceptance gate: removing head-of-line blocking must show up as
    // a strictly better client-observed tail on the mixed workload
    assert!(
        out.pipe_p99_us < out.serial_p99_us,
        "pipelined p99 ({:.0} µs) must beat serial p99 ({:.0} µs)",
        out.pipe_p99_us,
        out.serial_p99_us
    );
    server.stop();
    drop(server);
    drop(store);
    let _ = std::fs::remove_dir_all(&dir);
    out
}

/// Machine-readable serve-bench results, tracked across PRs
/// (`BENCH_serve.json` in the working directory).
#[allow(clippy::too_many_arguments)]
fn write_serve_json(
    n_trees: usize,
    n_rows: usize,
    t_row: &Timing,
    base_rps: f64,
    cold_rps: f64,
    warm_rps: f64,
    scaling: &[(usize, f64)],
    row_axis: (f64, f64),
    plans: (u64, u64, u64),
    pipeline: &PipelineBench,
) {
    let scaling_json: Vec<String> = scaling
        .iter()
        .map(|(w, r)| format!("{{\"workers\": {w}, \"rows_per_sec\": {r:.1}}}"))
        .collect();
    let lines = [
        "{".to_string(),
        "  \"bench\": \"hotpath serve\",".to_string(),
        format!("  \"trees\": {n_trees},"),
        format!("  \"rows\": {n_rows},"),
        format!(
            "  \"single_row_us\": {{\"p50\": {:.2}, \"p99\": {:.2}}},",
            t_row.median * 1e6,
            t_row.p99 * 1e6
        ),
        format!(
            "  \"rows_per_sec\": {{\"baseline_redecode\": {base_rps:.1}, \
             \"flat_cold\": {cold_rps:.1}, \"flat_warm\": {warm_rps:.1}}},"
        ),
        format!(
            "  \"speedup_vs_baseline\": {{\"flat_cold\": {:.3}, \"flat_warm\": {:.3}}},",
            cold_rps / base_rps.max(1e-9),
            warm_rps / base_rps.max(1e-9)
        ),
        format!("  \"worker_scaling\": [{}],", scaling_json.join(", ")),
        format!(
            "  \"row_axis_rows_per_sec\": {{\"workers_1\": {:.1}, \"workers_8\": {:.1}}},",
            row_axis.0, row_axis.1
        ),
        format!(
            "  \"plan_cache\": {{\"hits\": {}, \"misses\": {}, \"resident_bytes\": {}}},",
            plans.0, plans.1, plans.2
        ),
        format!(
            "  \"pipeline\": {{\"requests\": {}, \
             \"serial_us\": {{\"p50\": {:.2}, \"p99\": {:.2}}}, \
             \"pipelined_us\": {{\"p50\": {:.2}, \"p99\": {:.2}}}, \
             \"p99_speedup\": {:.3}}}",
            pipeline.requests,
            pipeline.serial_p50_us,
            pipeline.serial_p99_us,
            pipeline.pipe_p50_us,
            pipeline.pipe_p99_us,
            pipeline.serial_p99_us / pipeline.pipe_p99_us.max(1e-9)
        ),
        "}".to_string(),
    ];
    let json = lines.join("\n") + "\n";
    match std::fs::write("BENCH_serve.json", &json) {
        Ok(()) => println!("wrote BENCH_serve.json"),
        Err(e) => eprintln!("could not write BENCH_serve.json: {e}"),
    }
}

fn bench_spill(cfg: &rf_compress::util::bench::BenchConfig) {
    use rf_compress::coordinator::store::{ModelStore, ObsValue};
    use rf_compress::util::mmap::Mmap;

    println!("== tiered store: mmap reload vs cold parse ==");
    let quick = cfg.args.flag("quick");
    let budget = if quick { 0.05 } else { 0.5 };
    let spill_cap: u64 = cfg.args.get_or("spill-bytes", 64u64 << 20);
    let dir = std::env::temp_dir().join(format!("rfc-spill-bench-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let ds = synthetic::airfoil_classification(1234);
    let n_trees = if quick { cfg.trees.min(16).max(4) } else { cfg.trees.max(50) };
    let forest = Forest::train(&ds, &ForestParams::classification(n_trees), cfg.seed);
    let cf = CompressedForest::compress(&forest, &ds, &CompressOptions::default()).unwrap();
    let one = cf.total_bytes();
    println!(
        "container: {} trees, {} (spill-tier cap {})",
        n_trees,
        rf_compress::util::stats::human_bytes(one),
        rf_compress::util::stats::human_bytes(spill_cap)
    );
    if one > spill_cap {
        println!("container exceeds --spill-bytes; skipping the spill stage");
        let _ = std::fs::remove_dir_all(&dir);
        return;
    }

    // correctness gate (the CI spill-smoke stage trips on any divergence):
    // predictions from a Resident, a Spilled-then-reloaded, and a
    // freshly-parsed model must be identical
    let store = ModelStore::with_budget(2 * one).spill_dir(&dir).spill_bytes(spill_cap);
    store.insert("m", &cf).unwrap();
    let rows: Vec<Vec<ObsValue>> = (0..ds.num_rows().min(64))
        .map(|r| {
            ds.features
                .iter()
                .map(|f| match &f.column {
                    rf_compress::data::Column::Numeric(v) => ObsValue::Num(v[r]),
                    rf_compress::data::Column::Categorical { values, .. } => {
                        ObsValue::Cat(values[r])
                    }
                })
                .collect()
        })
        .collect();
    let resident_out = store.predict_batch("m", &rows).unwrap();
    assert!(store.spill("m").unwrap(), "spill must succeed under the cap");
    assert!(store.is_spilled("m"));
    let reloaded_out = store.predict_batch("m", &rows).unwrap();
    assert_eq!(reloaded_out, resident_out, "reload diverges from the resident model");
    let fresh = CompressedPredictor::new(cf.parse().unwrap()).unwrap();
    match fresh.predict_all(&ds).unwrap() {
        rf_compress::forest::forest::Predictions::Classes(cs) => {
            for (i, out) in resident_out.iter().enumerate() {
                assert_eq!(
                    *out,
                    rf_compress::compress::predict::PredictOne::Class(cs[i]),
                    "row {i}: fresh parse diverges"
                );
            }
        }
        _ => unreachable!("classification forest"),
    }

    // a container file both timing paths read back
    let file = dir.join("bench-model.rfcz");
    std::fs::write(&file, &cf.bytes).unwrap();

    // cold parse: read the whole file into a heap buffer, then parse —
    // what a reload would cost without the mmap seam
    let t_cold = time_it(budget, 5, || {
        let bytes = std::fs::read(&file).unwrap();
        let cf = CompressedForest::from_bytes(bytes).unwrap();
        let p = CompressedPredictor::new(cf.parse().unwrap()).unwrap();
        assert_eq!(p.num_trees(), n_trees);
    });
    // mmap reload: map + parse; payload bytes are never copied, the kernel
    // pages them in on first decode
    let t_mmap = time_it(budget, 5, || {
        let map = Mmap::map_path(&file).unwrap();
        let pc = rf_compress::compress::container::parse_arc(map).unwrap();
        let p = CompressedPredictor::new(pc).unwrap();
        assert_eq!(p.num_trees(), n_trees);
    });
    // end-to-end round trip through the store: force a spill (disk write),
    // then a single-row predict that triggers the mmap reload
    let vals = rows[0].clone();
    let t_round = time_it(budget, 5, || {
        store.spill("m").unwrap();
        store.predict("m", &vals).unwrap();
    });

    let us = |s: f64| s * 1e6;
    let mut t = Table::new(&["path", "p50", "p99", "vs cold"]);
    t.row(&[
        "cold parse (read + parse)".into(),
        format!("{:.1} µs", us(t_cold.median)),
        format!("{:.1} µs", us(t_cold.p99)),
        "1.00x".into(),
    ]);
    t.row(&[
        "mmap reload (map + parse)".into(),
        format!("{:.1} µs", us(t_mmap.median)),
        format!("{:.1} µs", us(t_mmap.p99)),
        format!("{:.2}x", t_cold.median / t_mmap.median),
    ]);
    t.row(&[
        "store spill+reload round trip".into(),
        format!("{:.1} µs", us(t_round.median)),
        format!("{:.1} µs", us(t_round.p99)),
        "-".into(),
    ]);
    t.print();
    let s = store.stats();
    println!("store: spills={} reloads={} evictions={}", s.spills, s.reloads, s.evictions);
    assert!(s.spills > 0 && s.reloads > 0, "the round trip must exercise both transitions");

    let json = [
        "{".to_string(),
        "  \"bench\": \"hotpath spill\",".to_string(),
        format!("  \"trees\": {n_trees},"),
        format!("  \"container_bytes\": {one},"),
        format!(
            "  \"cold_parse_us\": {{\"p50\": {:.2}, \"p99\": {:.2}}},",
            us(t_cold.median),
            us(t_cold.p99)
        ),
        format!(
            "  \"mmap_reload_us\": {{\"p50\": {:.2}, \"p99\": {:.2}}},",
            us(t_mmap.median),
            us(t_mmap.p99)
        ),
        format!(
            "  \"spill_roundtrip_us\": {{\"p50\": {:.2}, \"p99\": {:.2}}},",
            us(t_round.median),
            us(t_round.p99)
        ),
        format!("  \"reload_speedup_vs_cold\": {:.3},", t_cold.median / t_mmap.median.max(1e-9)),
        format!("  \"spills\": {}, \"reloads\": {}", s.spills, s.reloads),
        "}".to_string(),
    ]
    .join("\n")
        + "\n";
    match std::fs::write("BENCH_spill.json", &json) {
        Ok(()) => println!("wrote BENCH_spill.json"),
        Err(e) => eprintln!("could not write BENCH_spill.json: {e}"),
    }
    drop(store);
    let _ = std::fs::remove_dir_all(&dir);
    println!();
}

fn bench_pack(cfg: &rf_compress::util::bench::BenchConfig) {
    use rf_compress::coordinator::store::{ModelStore, ObsValue};
    use rf_compress::forest::TreeParams;
    use rf_compress::pack::{compress_cohort, PackArchive, PackBuilder};
    use rf_compress::util::mmap::Mmap;
    use rf_compress::util::stats::human_bytes;

    println!("== model packs: one RFPK archive vs per-file spill ==");
    let quick = cfg.args.flag("quick");
    let members: usize = cfg.args.get_or("members", if quick { 96 } else { 1000 });
    let dir = std::env::temp_dir().join(format!("rfc-pack-bench-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    // the ROADMAP scenario: many tiny per-user models (≤ 4 KiB each) on a
    // common schema — depth-limited 2-tree forests over iris land well
    // under the page size once the cohort shares its codebooks
    let ds = synthetic::iris(1234);
    let params = ForestParams {
        tree: TreeParams { mtry: Some(2), min_leaf: 2, max_depth: 3 },
        ..ForestParams::classification(2)
    };
    let forests: Vec<Forest> = (0..members)
        .map(|i| Forest::train(&ds, &params, cfg.seed + i as u64))
        .collect();
    let cohort = compress_cohort(&forests, &ds, &CompressOptions::default()).unwrap();
    let sizes: Vec<u64> = cohort.iter().map(|cf| cf.total_bytes()).collect();
    let mean_model = sizes.iter().sum::<u64>() as f64 / members as f64;
    let max_model = *sizes.iter().max().unwrap();
    println!(
        "cohort: {members} members, {:.0} B mean / {} max per standalone container{}",
        mean_model,
        human_bytes(max_model),
        if max_model > 4096 { "  (WARNING: over the 4 KiB scenario)" } else { "" }
    );

    // one archive...
    let mut builder = PackBuilder::new();
    for (i, cf) in cohort.iter().enumerate() {
        builder.add(&format!("user-{i:04}"), cf.bytes.clone()).unwrap();
    }
    let pack_path = dir.join("cohort.rfpk");
    let stats = builder.write(&pack_path).unwrap();
    let pack = PackArchive::open(&pack_path).unwrap();

    // ...vs one file per member (the spill tier's layout)
    let files_dir = dir.join("per-file");
    std::fs::create_dir_all(&files_dir).unwrap();
    let files: Vec<std::path::PathBuf> = cohort
        .iter()
        .enumerate()
        .map(|(i, cf)| {
            let p = files_dir.join(format!("user-{i:04}.rfcz"));
            std::fs::write(&p, &cf.bytes).unwrap();
            p
        })
        .collect();

    // correctness gate (the CI pack-smoke stage trips on any divergence):
    // every member must extract bit-identical to its source container, and
    // sampled members must decode to their original forests
    for (i, cf) in cohort.iter().enumerate() {
        assert_eq!(
            pack.extract_member(i).unwrap()[..],
            cf.bytes[..],
            "member {i} extraction must be bit-identical"
        );
    }
    for i in (0..members).step_by((members / 16).max(1)) {
        let pc = pack.parse_member(i).unwrap();
        let g = rf_compress::compress::pipeline::decompress_container(&pc).unwrap();
        assert!(g.identical(&forests[i]), "member {i} must decode losslessly");
    }

    // bytes on disk: the archive is one file (page waste amortized across
    // the cohort); per-file pays it per member
    const PAGE: u64 = 4096;
    let round4k = |b: u64| b.div_ceil(PAGE) * PAGE;
    let pack_disk = round4k(stats.archive_bytes);
    let perfile_logical: u64 = sizes.iter().sum();
    let perfile_disk: u64 = sizes.iter().map(|&b| round4k(b)).sum();
    let mut t = Table::new(&["storage", "bytes on disk", "bytes/model", "vs per-file"]);
    t.row(&[
        "per-file spill (4 KiB pages)".into(),
        human_bytes(perfile_disk),
        format!("{:.0}", perfile_disk as f64 / members as f64),
        "1.00x".into(),
    ]);
    t.row(&[
        "pack archive".into(),
        human_bytes(pack_disk),
        format!("{:.0}", pack_disk as f64 / members as f64),
        format!("{:.2}x", perfile_disk as f64 / pack_disk as f64),
    ]);
    t.print();
    println!(
        "shared-codebook dedup: {} blob(s), {} excised ({} logical total)",
        stats.blobs,
        human_bytes(stats.shared_saved_bytes),
        human_bytes(stats.logical_bytes)
    );
    assert!(
        pack_disk < perfile_disk,
        "a pack must beat per-file page-rounded storage ({pack_disk} vs {perfile_disk})"
    );

    // member reload latency: pack = parse out of the already-open mapping;
    // per-file = open + mmap + parse per model (the spill reload path).
    // Per-member samples across passes give honest p50/p99 tails.
    let passes = if quick { 2 } else { 3 };
    let mut pack_us = Vec::with_capacity(members * passes);
    let mut file_us = Vec::with_capacity(members * passes);
    for _ in 0..passes {
        for i in 0..members {
            let t0 = std::time::Instant::now();
            let p = CompressedPredictor::new(pack.parse_member(i).unwrap()).unwrap();
            assert_eq!(p.num_trees(), forests[i].num_trees());
            pack_us.push(t0.elapsed().as_secs_f64() * 1e6);
        }
        for (i, path) in files.iter().enumerate() {
            let t0 = std::time::Instant::now();
            let map = Mmap::map_path(path).unwrap();
            let pc = rf_compress::compress::container::parse_arc(map).unwrap();
            let p = CompressedPredictor::new(pc).unwrap();
            assert_eq!(p.num_trees(), forests[i].num_trees());
            file_us.push(t0.elapsed().as_secs_f64() * 1e6);
        }
    }
    let quantile = rf_compress::util::stats::quantile;
    let (pack_p50, pack_p99) = (quantile(&pack_us, 0.5), quantile(&pack_us, 0.99));
    let (file_p50, file_p99) = (quantile(&file_us, 0.5), quantile(&file_us, 0.99));
    let mut t = Table::new(&["member reload", "p50", "p99", "p99 vs per-file"]);
    t.row(&[
        "per-file (open+mmap+parse)".into(),
        format!("{file_p50:.1} µs"),
        format!("{file_p99:.1} µs"),
        "1.00x".into(),
    ]);
    t.row(&[
        "pack (parse off one mapping)".into(),
        format!("{pack_p50:.1} µs"),
        format!("{pack_p99:.1} µs"),
        format!("{:.2}x", file_p99 / pack_p99.max(1e-9)),
    ]);
    t.print();

    // end-to-end: a budgeted store churning through the whole cohort —
    // members load out of the pack and release back under pressure
    let budget = (mean_model as u64 * 8).max(max_model * 2);
    let store = ModelStore::with_budget(budget);
    let pack = std::sync::Arc::new(pack);
    store.attach_pack(&pack).unwrap();
    let vals: Vec<ObsValue> = ds
        .features
        .iter()
        .map(|f| match &f.column {
            rf_compress::data::Column::Numeric(v) => ObsValue::Num(v[0]),
            rf_compress::data::Column::Categorical { values, .. } => ObsValue::Cat(values[0]),
        })
        .collect();
    let t0 = std::time::Instant::now();
    for i in 0..members {
        store.predict(&format!("user-{i:04}"), &vals).unwrap();
    }
    let sweep_s = t0.elapsed().as_secs_f64();
    let s = store.stats();
    println!(
        "store sweep over {members} members under a {} budget: {:.0} members/s, \
         pack_loads={} pack_releases={} spills={} evictions={}",
        human_bytes(budget),
        members as f64 / sweep_s,
        s.pack_loads,
        s.pack_releases,
        s.spills,
        s.evictions
    );
    assert_eq!(s.evictions, 0, "pack members must release, never drop");
    assert_eq!(s.spills, 0, "pack members must never write spill files");

    // chain-read overhead: the same cohort served through a generation
    // chain at depth 1, 2, and 4 — what a stack of delta generations costs
    // a read (newest-first resolution + parse), and that a merge
    // compaction claws it back
    use rf_compress::pack::{compact_chain, CompactMode, PackChain};
    let keys: Vec<String> = (0..members).map(|i| format!("user-{i:04}")).collect();
    let chain_sample = |chain: &PackChain| -> Vec<f64> {
        let mut us = Vec::with_capacity(members * passes);
        for _ in 0..passes {
            for (i, key) in keys.iter().enumerate() {
                let t0 = std::time::Instant::now();
                let p = CompressedPredictor::new(chain.parse(key).unwrap()).unwrap();
                assert_eq!(p.num_trees(), forests[i].num_trees());
                us.push(t0.elapsed().as_secs_f64() * 1e6);
            }
        }
        us
    };
    let mut chain_rows: Vec<(String, f64, f64)> = Vec::new();
    let mut deepest = None;
    for depth in [1usize, 2, 4] {
        let cdir = dir.join(format!("chain-{depth}"));
        let mut chain = PackChain::create(&cdir).unwrap();
        // round-robin the cohort into `depth` delta generations
        for leg in 0..depth {
            let batch: Vec<_> = cohort
                .iter()
                .enumerate()
                .filter(|(i, _)| i % depth == leg)
                .map(|(i, cf)| (keys[i].clone(), cf.bytes.clone()))
                .collect();
            chain.append_members(&batch).unwrap();
        }
        assert_eq!(chain.generation_count(), depth);
        assert_eq!(chain.live_len(), members, "every member stays live");
        let us = chain_sample(&chain);
        chain_rows.push((
            format!("chain, {depth} generation(s)"),
            quantile(&us, 0.5),
            quantile(&us, 0.99),
        ));
        if depth == 4 {
            deepest = Some(chain);
        }
    }
    // compact the deepest chain in place: the depth overhead must not
    // outlive the merge
    let mut chain = deepest.unwrap();
    let cstats = compact_chain(&mut chain, CompactMode::Merge).unwrap();
    assert_eq!(chain.generation_count(), 1, "merge collapses the chain");
    let us = chain_sample(&chain);
    chain_rows.push(("chain, compacted 4 -> 1".to_string(), quantile(&us, 0.5), quantile(&us, 0.99)));
    let mut t = Table::new(&["chain read (parse)", "p50", "p99", "p99 vs 1 gen"]);
    let gen1_p99 = chain_rows[0].2;
    for (label, p50, p99) in &chain_rows {
        t.row(&[
            label.clone(),
            format!("{p50:.1} µs"),
            format!("{p99:.1} µs"),
            format!("{:.2}x", p99 / gen1_p99.max(1e-9)),
        ]);
    }
    t.print();
    println!(
        "merge compaction: {} generations -> 1, {} -> {} archive bytes",
        cstats.generations_before,
        human_bytes(cstats.bytes_before),
        human_bytes(cstats.bytes_after)
    );

    let json = [
        "{".to_string(),
        "  \"bench\": \"hotpath pack\",".to_string(),
        format!("  \"members\": {members},"),
        format!(
            "  \"model_bytes\": {{\"mean\": {mean_model:.1}, \"max\": {max_model}}},"
        ),
        format!(
            "  \"disk_bytes\": {{\"pack\": {pack_disk}, \"per_file_4k\": {perfile_disk}, \
             \"per_file_logical\": {perfile_logical}}},"
        ),
        format!(
            "  \"bytes_per_model\": {{\"pack\": {:.1}, \"per_file_4k\": {:.1}}},",
            pack_disk as f64 / members as f64,
            perfile_disk as f64 / members as f64
        ),
        format!(
            "  \"reload_us\": {{\"pack\": {{\"p50\": {pack_p50:.2}, \"p99\": {pack_p99:.2}}}, \
             \"per_file\": {{\"p50\": {file_p50:.2}, \"p99\": {file_p99:.2}}}}},"
        ),
        format!(
            "  \"shared\": {{\"blobs\": {}, \"shared_members\": {}, \"saved_bytes\": {}}},",
            stats.blobs, stats.shared_members, stats.shared_saved_bytes
        ),
        format!(
            "  \"store_sweep\": {{\"members_per_sec\": {:.1}, \"pack_loads\": {}, \
             \"pack_releases\": {}}},",
            members as f64 / sweep_s,
            s.pack_loads,
            s.pack_releases
        ),
        format!(
            "  \"chain_read_us\": {{\"gen1\": {{\"p50\": {:.2}, \"p99\": {:.2}}}, \
             \"gen2\": {{\"p50\": {:.2}, \"p99\": {:.2}}}, \
             \"gen4\": {{\"p50\": {:.2}, \"p99\": {:.2}}}, \
             \"compacted\": {{\"p50\": {:.2}, \"p99\": {:.2}}}}},",
            chain_rows[0].1,
            chain_rows[0].2,
            chain_rows[1].1,
            chain_rows[1].2,
            chain_rows[2].1,
            chain_rows[2].2,
            chain_rows[3].1,
            chain_rows[3].2
        ),
        format!(
            "  \"chain_p99_gen4_vs_gen1\": {:.3}",
            chain_rows[2].2 / gen1_p99.max(1e-9)
        ),
        "}".to_string(),
    ]
    .join("\n")
        + "\n";
    match std::fs::write("BENCH_pack.json", &json) {
        Ok(()) => println!("wrote BENCH_pack.json"),
        Err(e) => eprintln!("could not write BENCH_pack.json: {e}"),
    }
    drop(store);
    let _ = std::fs::remove_dir_all(&dir);
    println!();
}

fn bench_codec() {
    println!("== codec microbenches ==");
    let mut rng = Pcg64::new(3);
    // skewed 64-symbol alphabet
    let weights: Vec<f64> = (0..64).map(|i| 1.0 / (i + 1) as f64).collect();
    let code = rf_compress::coding::huffman::HuffmanCode::from_weights(&weights).unwrap();
    let syms: Vec<u32> = (0..100_000)
        .map(|_| {
            let mut u = rng.gen_f64() * weights.iter().sum::<f64>();
            for (i, &w) in weights.iter().enumerate() {
                if u < w {
                    return i as u32;
                }
                u -= w;
            }
            63
        })
        .collect();
    let mut w = rf_compress::coding::bitio::BitWriter::new();
    code.encode_all(&syms, &mut w).unwrap();
    let bytes = w.as_bytes().to_vec();
    let dec = code.decoder();

    let t_enc = time_it(0.5, 3, || {
        let mut w = rf_compress::coding::bitio::BitWriter::new();
        code.encode_all(&syms, &mut w).unwrap();
    });
    let t_dec = time_it(0.5, 3, || {
        let mut r = rf_compress::coding::bitio::BitReader::new(&bytes);
        dec.decode_all(&mut r, syms.len()).unwrap();
    });

    // LZ on repetitive input
    let data: Vec<u8> = b"1111001001001111001000".iter().cycle().take(200_000).copied().collect();
    let t_lz = time_it(0.5, 3, || {
        rf_compress::coding::lz::compress_to_bytes(&data);
    });

    let model = rf_compress::coding::arith::FreqModel::from_freqs(&[95, 5]).unwrap();
    let bits: Vec<u32> = (0..100_000).map(|_| rng.gen_bool(0.05) as u32).collect();
    let t_arith = time_it(0.5, 3, || {
        let mut w = rf_compress::coding::bitio::BitWriter::new();
        rf_compress::coding::arith::encode_sequence(&model, &bits, &mut w).unwrap();
    });

    let mut t = Table::new(&["codec", "time", "Msym/s"]);
    t.row(&["huffman encode (100k syms)".into(), format!("{t_enc}"), format!("{:.1}", t_enc.per_sec(0.1))]);
    t.row(&["huffman decode (100k syms)".into(), format!("{t_dec}"), format!("{:.1}", t_dec.per_sec(0.1))]);
    t.row(&["lzss compress (200 KB)".into(), format!("{t_lz}"), format!("{:.1} MB/s", t_lz.per_sec(0.2))]);
    t.row(&["arith encode (100k bits)".into(), format!("{t_arith}"), format!("{:.1}", t_arith.per_sec(0.1))]);
    t.print();
}
