//! Ablations over the paper's design claims:
//!
//! * `alpha`        — §6: 64-bit fit α ⇒ 2–3 clusters; 32-bit ⇒ more (~7)
//! * `zaks`         — §3.1: LZ on the concatenated Zaks stream vs gzip vs
//!                    raw packing vs per-tree arithmetic coding
//! * `crt`          — §8: completely-randomized trees compress worse
//! * `conditioning` — §3.2.2: (depth, father) vs depth-only vs none
//! * `coder`        — §2.2/§4: arithmetic vs Huffman on binary fits, and
//!                    zstd-19 as a modern general-purpose comparator
//! * `stages`       — transform-stage codec pipeline: per-stage
//!                    encode/decode throughput on a real fit-table stream,
//!                    and container sizes under candidate chains vs the
//!                    fixed pipeline
//!
//! Run all: `cargo bench --bench ablations`; one: `-- alpha`.

use rf_compress::baseline;
use rf_compress::coding::arith::FreqModel;
use rf_compress::coding::bitio::{BitReader, BitWriter};
use rf_compress::compress::{CompressOptions, CompressedForest};
use rf_compress::coordinator::Coordinator;
use rf_compress::data::synthetic;
use rf_compress::forest::{crt, Forest, ForestParams};
use rf_compress::model::ModelConditioning;
use rf_compress::util::bench::{bench_config, Table};
use rf_compress::util::stats::human_bytes;
use rf_compress::zaks;

fn main() {
    let cfg = bench_config(60);
    let which = cfg.args.positional(0).map(|s| s.to_string());
    let run = |name: &str| which.as_deref().map_or(true, |w| w == name);

    if run("alpha") {
        ablation_alpha(&cfg);
    }
    if run("zaks") {
        ablation_zaks(&cfg);
    }
    if run("crt") {
        ablation_crt(&cfg);
    }
    if run("conditioning") {
        ablation_conditioning(&cfg);
    }
    if run("coder") {
        ablation_coder(&cfg);
    }
    if run("stages") {
        ablation_stages(&cfg);
    }
}

/// §6: the fit-dictionary cost α controls the chosen number of clusters.
fn ablation_alpha(cfg: &rf_compress::util::bench::BenchConfig) {
    println!("== ablation: α (fit representation bits) vs chosen clusters ==");
    let ds = synthetic::liberty_classification(1234);
    let mut coord = Coordinator::native_only();
    let forest = coord.train(&ds, cfg.trees.min(40), cfg.seed);
    let mut t = Table::new(&["fit α bits", "max clusters over families", "mean clusters", "total size"]);
    for bits in [64u32, 32, 16, 8] {
        let opts = CompressOptions { fit_alpha_bits: bits, k_max: 10, ..Default::default() };
        let (cf, report) = coord.run_job(&ds, &forest, &opts, 0.0).unwrap();
        let ks: Vec<usize> = report.cluster_ks.iter().map(|(_, k)| *k).collect();
        let max = ks.iter().max().copied().unwrap_or(0);
        let mean = ks.iter().sum::<usize>() as f64 / ks.len().max(1) as f64;
        t.row(&[
            bits.to_string(),
            max.to_string(),
            format!("{mean:.2}"),
            human_bytes(cf.total_bytes()),
        ]);
    }
    t.print();
    println!("paper §6: 64-bit α → 2–3 clusters; 32-bit → ≈7 (more clusters as α shrinks)\n");
}

/// §3.1: structure coding choices on the concatenated Zaks stream.
fn ablation_zaks(cfg: &rf_compress::util::bench::BenchConfig) {
    println!("== ablation: tree-structure coding (§3.1) ==");
    let ds = synthetic::adults(1234);
    let forest = Forest::train(
        &ds,
        &ForestParams::classification(cfg.trees.min(40)),
        cfg.seed,
    );
    let (bits, _) = zaks::concat_forest_zaks(&forest.trees);
    let packed = rf_compress::compress::container::pack_bits(&bits);

    let lz = rf_compress::coding::lz::compress_to_bytes(&packed);
    let gz = baseline::gzip::gzip(&packed);
    let zs = baseline::gzip::zstd_strong(&packed);
    // per-symbol arithmetic coding with a global Bernoulli model (ignores
    // the repetition structure the paper's LZ choice exploits)
    let arith = {
        let ones = bits.iter().filter(|&&b| b).count() as f64;
        let p1 = (ones / bits.len() as f64).clamp(1e-6, 1.0 - 1e-6);
        let model = FreqModel::from_probs(&[1.0 - p1, p1]).unwrap();
        let syms: Vec<u32> = bits.iter().map(|&b| b as u32).collect();
        let mut w = BitWriter::new();
        rf_compress::coding::arith::encode_sequence(&model, &syms, &mut w).unwrap();
        w.into_bytes()
    };

    let mut t = Table::new(&["method", "bytes", "bits/node"]);
    let per = |n: usize| n as f64 * 8.0 / bits.len() as f64;
    t.row(&["raw packed".into(), packed.len().to_string(), format!("{:.3}", per(packed.len()))]);
    t.row(&["arith (iid Bernoulli)".into(), arith.len().to_string(), format!("{:.3}", per(arith.len()))]);
    t.row(&["LZSS (ours, paper §3.1)".into(), lz.len().to_string(), format!("{:.3}", per(lz.len()))]);
    t.row(&["gzip".into(), gz.len().to_string(), format!("{:.3}", per(gz.len()))]);
    t.row(&["zstd-19".into(), zs.len().to_string(), format!("{:.3}", per(zs.len()))]);
    t.print();
    // sanity: LZ round-trips
    let mut r = BitReader::new(&lz);
    assert_eq!(rf_compress::coding::lz::decompress(&mut r).unwrap(), packed);
    println!();
}

/// §8: CRT forests have higher split entropy ⇒ worse compression.
fn ablation_crt(cfg: &rf_compress::util::bench::BenchConfig) {
    println!("== ablation: CART vs completely-randomized trees (§8) ==");
    let ds = synthetic::airfoil_classification(1234);
    let n = cfg.trees.min(60);
    let params = ForestParams::classification(n);
    let cart = Forest::train(&ds, &params, cfg.seed);
    let crt_forest = crt::train_crt(&ds, &params, cfg.seed);
    let opts = CompressOptions::default();
    let cf_cart = CompressedForest::compress(&cart, &ds, &opts).unwrap();
    let cf_crt = CompressedForest::compress(&crt_forest, &ds, &opts).unwrap();
    // CRT trees grow much larger on the same data, so total-size/node would
    // conflate amortization with codability; the paper's §8 claim is about
    // the *split distributions*, so compare the vars+splits payload per
    // internal node (dictionaries excluded on both sides).
    let split_bits = |cf: &CompressedForest, f: &Forest| {
        let internal: usize = f.trees.iter().map(|t| t.internal_count()).sum();
        (cf.sizes.var_names + cf.sizes.split_values) as f64 * 8.0 / internal as f64
    };
    let mut t = Table::new(&["forest", "nodes", "compressed", "split payload bits/internal"]);
    t.row(&[
        "CART (random forest)".into(),
        cart.total_nodes().to_string(),
        human_bytes(cf_cart.total_bytes()),
        format!("{:.2}", split_bits(&cf_cart, &cart)),
    ]);
    t.row(&[
        "CRT (extra-random)".into(),
        crt_forest.total_nodes().to_string(),
        human_bytes(cf_crt.total_bytes()),
        format!("{:.2}", split_bits(&cf_crt, &crt_forest)),
    ]);
    t.print();
    let a = split_bits(&cf_cart, &cart);
    let b = split_bits(&cf_crt, &crt_forest);
    println!(
        "paper §8 predicts CRT split info is worse to encode: CART {a:.2} vs CRT {b:.2} bits/internal → {}\n",
        if b > a { "CONFIRMED" } else { "NOT CONFIRMED at this scale" }
    );
}

/// §3.2.2: what the (depth, father) conditioning buys.
fn ablation_conditioning(cfg: &rf_compress::util::bench::BenchConfig) {
    println!("== ablation: model conditioning (§3.2.2) ==");
    let ds = synthetic::liberty_classification(1234);
    let mut coord = Coordinator::native_only();
    let forest = coord.train(&ds, cfg.trees.min(40), cfg.seed);
    let mut t = Table::new(&["conditioning", "total", "vars+splits payload", "dict+maps"]);
    for (name, c) in [
        ("none", ModelConditioning::None),
        ("depth-only", ModelConditioning::DepthOnly),
        ("depth+father (paper)", ModelConditioning::DepthFather),
    ] {
        let opts = CompressOptions { conditioning: c, ..Default::default() };
        let (cf, _) = coord.run_job(&ds, &forest, &opts, 0.0).unwrap();
        assert!(cf.decompress().unwrap().identical(&forest));
        let cols = cf.sizes.paper_columns();
        t.row(&[
            name.into(),
            human_bytes(cf.total_bytes()),
            human_bytes(cols.var_names + cols.split_values),
            human_bytes(cols.dict),
        ]);
    }
    t.print();
    println!("richer conditioning shrinks payload at the cost of more models/dictionaries\n");
}

/// Transform-stage codec pipeline: per-stage throughput + chain sizes.
fn ablation_stages(cfg: &rf_compress::util::bench::BenchConfig) {
    use rf_compress::coding::stage::{parse_chain, BufferList, SectionChains, StageSpec};
    use rf_compress::forest::Fit;
    use rf_compress::util::bench::time_it;

    println!("== ablation: transform-stage codec pipeline ==");
    let ds = synthetic::airfoil_regression(1234);
    let forest =
        Forest::train(&ds, &ForestParams::regression(cfg.trees.min(30)), cfg.seed);
    // the raw f64 byte stream a fit chain sees: every node fit in order
    let vals: Vec<f64> = forest
        .trees
        .iter()
        .flat_map(|t| t.nodes.iter())
        .filter_map(|n| match n.fit {
            Fit::Regression(v) => Some(v),
            Fit::Class(_) => None,
        })
        .collect();
    let mut bytes = Vec::with_capacity(vals.len() * 8);
    for v in &vals {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    let mb = bytes.len() as f64 / (1024.0 * 1024.0);
    let mut t = Table::new(&["stage", "out/in", "enc MB/s", "dec MB/s"]);
    for spec in [
        StageSpec::DeltaU64,
        StageSpec::XorU64,
        StageSpec::ColumnSplit(8),
        StageSpec::Lzss,
        StageSpec::Huffman,
        StageSpec::Arith,
        StageSpec::ConvertF64F32,
        StageSpec::ConvertF64Bf16,
    ] {
        let st = spec.build();
        let input = BufferList::from_single(bytes.clone());
        let enc = st.encode(&input).unwrap();
        let te = time_it(0.1, 3, || {
            std::hint::black_box(st.encode(&input).unwrap());
        });
        let td = time_it(0.1, 3, || {
            std::hint::black_box(st.decode(&enc).unwrap());
        });
        t.row(&[
            spec.name(),
            format!("{:.3}", enc.total_bytes() as f64 / bytes.len().max(1) as f64),
            format!("{:.1}", mb / te.median.max(1e-12)),
            format!("{:.1}", mb / td.median.max(1e-12)),
        ]);
    }
    t.print();

    // whole containers: candidate chains vs the fixed pipeline (no chains)
    let base = CompressedForest::compress(&forest, &ds, &CompressOptions::default()).unwrap();
    let mut t = Table::new(&["chains (struct | split | fit)", "container", "vs fixed"]);
    for (s, sp, f) in [
        ("-", "-", "-"),
        ("lzss", "delta+lzss", "-"),
        ("-", "split8+lzss", "split8+huff"),
        ("-", "-", "bf16+lzss"),
    ] {
        let chains = SectionChains {
            structure: parse_chain(s).unwrap(),
            split_tables: parse_chain(sp).unwrap(),
            fit_table: parse_chain(f).unwrap(),
        };
        let lossy = chains.is_lossy();
        let opts = CompressOptions { chains, ..Default::default() };
        let cf = CompressedForest::compress(&forest, &ds, &opts).unwrap();
        if !lossy {
            assert!(
                cf.decompress().unwrap().identical(&forest),
                "lossless chain must round-trip bit-exactly"
            );
        }
        t.row(&[
            format!("{s} | {sp} | {f}{}", if lossy { " (lossy)" } else { "" }),
            human_bytes(cf.total_bytes()),
            format!(
                "{:+.1}%",
                (cf.total_bytes() as f64 / base.total_bytes() as f64 - 1.0) * 100.0
            ),
        ]);
    }
    t.print();
    println!("empty chains reproduce the fixed pipeline exactly (the +0.0% row)\n");
}

/// §4: arithmetic coding beats Huffman on skewed binary fits.
fn ablation_coder(cfg: &rf_compress::util::bench::BenchConfig) {
    println!("== ablation: binary-fit coder (arith vs Huffman ≥1 bit/fit) ==");
    let ds = synthetic::liberty_classification(1234);
    let forest = Forest::train(
        &ds,
        &ForestParams::classification(cfg.trees.min(30)),
        cfg.seed,
    );
    let opts = CompressOptions::default();
    let cf = CompressedForest::compress(&forest, &ds, &opts).unwrap();
    let total_nodes = forest.total_nodes() as f64;
    let fit_bits = cf.sizes.fits as f64 * 8.0;
    println!(
        "arith fit section: {:.3} bits/fit over {} fits (Huffman floor is 1.0)",
        fit_bits / total_nodes,
        total_nodes as u64,
    );
    // a modern general-purpose comparator over the whole model
    let (light_raw, _) = baseline::light_representation(&forest);
    let zs = baseline::gzip::zstd_strong(&light_raw);
    println!(
        "whole-model comparison: ours {} vs zstd-19(light) {}\n",
        human_bytes(cf.total_bytes()),
        human_bytes(zs.len() as u64)
    );
}
