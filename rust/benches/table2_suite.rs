//! **Table 2** — compression of 1000-tree forests over the 13 evaluation
//! datasets: standard vs light vs Algorithm 1.
//!
//! ```text
//! cargo bench --bench table2_suite               # 30 trees/forest (scaled)
//! cargo bench --bench table2_suite -- --trees 100
//! cargo bench --bench table2_suite -- --paper-scale    # 1000 trees (slow)
//! cargo bench --bench table2_suite -- --only iris,wages
//! ```
//!
//! Reproduced quantities (synthetic data, scaled tree counts): the ordering
//! ours < light < standard on every row, larger ratios for classification
//! than regression (the 64-bit fits dominate regression, §6), and ratios
//! that grow toward the paper's 1:6 (light) / 1:70 (standard) as `--trees`
//! rises. Paper MBs are printed alongside for reference.

use rf_compress::compress::CompressOptions;
use rf_compress::coordinator::Coordinator;
use rf_compress::data::synthetic::table2_suite;
use rf_compress::util::bench::{bench_config, Table};
use rf_compress::util::stats::human_bytes;

fn main() {
    let cfg = bench_config(30);
    let only: Option<Vec<String>> = cfg.args.get_list("only");
    println!("== Table 2: {} trees per forest ==", cfg.trees);
    let mut coord = if cfg.args.flag("native") {
        Coordinator::native_only()
    } else {
        Coordinator::new()
    };
    println!("engine: {}\n", coord.engine_name());

    let mut t = Table::new(&[
        "dataset",
        "obs×vars",
        "standard",
        "light",
        "ours",
        "vs std",
        "vs light",
        "paper std→ours",
    ]);
    let mut ratios_std_cls = Vec::new();
    let mut ratios_light_cls = Vec::new();
    let mut ratios_std_reg = Vec::new();
    let mut ratios_light_reg = Vec::new();

    for entry in table2_suite() {
        if let Some(only) = &only {
            if !only.iter().any(|k| k == entry.key) {
                continue;
            }
        }
        let ds = (entry.make)(cfg.args.get_or("data-seed", 1234));
        let classification = ds.target.is_classification();
        // paper accounting by default: numeric split values are coded as
        // observation ranks with the training data as side information,
        // exactly how Tables 1–2 count bytes; `--self-contained` opts out
        let opts = CompressOptions {
            dataset_indexed_splits: !cfg.args.flag("self-contained"),
            ..Default::default()
        };
        let (forest, cf, report) =
            match coord.train_and_compress(&ds, cfg.trees, cfg.seed, &opts) {
                Ok(x) => x,
                Err(e) => {
                    eprintln!("{}: {e:#}", entry.key);
                    continue;
                }
            };
        // verify losslessness on every row
        let restored = if opts.dataset_indexed_splits {
            cf.decompress_with_dataset(&ds).unwrap()
        } else {
            cf.decompress().unwrap()
        };
        assert!(restored.identical(&forest), "{}", entry.key);
        eprintln!(
            "  [{}] train {:.1}s compress {:.1}s",
            entry.key, report.train_s, report.compress_s
        );
        t.row(&[
            ds.name.clone(),
            format!("{}×{}", ds.num_rows(), ds.num_features()),
            human_bytes(report.standard_bytes),
            human_bytes(report.light_bytes),
            human_bytes(report.ours_bytes),
            format!("1:{:.1}", report.standard_ratio()),
            format!("1:{:.1}", report.light_ratio()),
            format!("{}→{} MB", entry.paper_standard_mb, entry.paper_ours_mb),
        ]);
        if classification {
            ratios_std_cls.push(report.standard_ratio());
            ratios_light_cls.push(report.light_ratio());
        } else {
            ratios_std_reg.push(report.standard_ratio());
            ratios_light_reg.push(report.light_ratio());
        }
    }
    t.print();

    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    println!("\nmean ratios, classification: 1:{:.1} vs standard, 1:{:.1} vs light   (paper: ~1:70, ~1:6 at 1000 trees)",
        mean(&ratios_std_cls), mean(&ratios_light_cls));
    println!("mean ratios, regression:     1:{:.1} vs standard, 1:{:.1} vs light   (paper: ~1:4.1, ~1:1.45)",
        mean(&ratios_std_reg), mean(&ratios_light_reg));
    if !ratios_light_cls.is_empty() && !ratios_light_reg.is_empty() {
        // the paper's fits effect: classification compresses better than
        // regression vs the *standard* baseline (where verbose fits cost
        // most). At scaled-down tree counts this holds on the full suite;
        // warn instead of assert so `--only` subsets stay usable.
        if mean(&ratios_std_cls) > mean(&ratios_std_reg) {
            println!("\nshape check PASSED: classification ratios > regression ratios (the paper's fits effect)");
        } else {
            println!("\nshape check NOT met at this scale/subset (classification {:.1} vs regression {:.1} vs standard)",
                mean(&ratios_std_cls), mean(&ratios_std_reg));
        }
    }
}
