//! **Figure 3** — lossy compression of the Bike Sharing regression forest
//! (same two sweeps as Figure 2 on a larger dataset). The paper's headline:
//! 2.38 MB → ~300 KB at 12-bit fits + 600/1000 trees with no significant
//! generalization change.
//!
//! ```text
//! cargo bench --bench fig3_bike_lossy                 # 150 trees (scaled)
//! cargo bench --bench fig3_bike_lossy -- --paper-scale
//! ```

use rf_compress::compress::CompressOptions;
use rf_compress::coordinator::Coordinator;
use rf_compress::data::synthetic;
use rf_compress::lossy::{self, theory};
use rf_compress::util::bench::{bench_config, Table};
use rf_compress::util::stats::human_bytes;
use rf_compress::util::Pcg64;

fn main() {
    let cfg = bench_config(150);
    println!("== Figure 3: Bike Sharing lossy compression, {} trees ==", cfg.trees);
    let ds = synthetic::bike_sharing(cfg.args.get_or("data-seed", 1234));
    let mut rng = Pcg64::new(cfg.seed);
    let tt = ds.train_test_split(0.8, &mut rng);
    let mut coord = if cfg.args.flag("native") {
        Coordinator::native_only()
    } else {
        Coordinator::new()
    };
    let t0 = std::time::Instant::now();
    let forest = coord.train(&tt.train, cfg.trees, cfg.seed);
    println!("train: {:.1}s", t0.elapsed().as_secs_f64());
    let full_mse = forest.test_error(&tt.test);
    let opts = CompressOptions::default();
    let (cf_full, _) = coord.run_job(&tt.train, &forest, &opts, 0.0).unwrap();
    println!(
        "lossless baseline: test MSE {full_mse:.4}, size {} (paper: 2.38 MB at 1000 trees)\n",
        human_bytes(cf_full.total_bytes())
    );

    println!("-- upper chart: fit quantization --");
    let mut t = Table::new(&["bits", "test MSE", "MSE/lossless", "size"]);
    for &bits in &cfg.args.get_list("bits").unwrap_or_else(|| vec![4, 6, 8, 10, 12, 14, 16]) {
        let (qf, _) = lossy::quantize_fits(&forest, bits, lossy::QuantizeMethod::Uniform).unwrap();
        let mse = qf.test_error(&tt.test);
        let (cf, _) = coord.run_job(&tt.train, &qf, &opts, 0.0).unwrap();
        t.row(&[
            bits.to_string(),
            format!("{mse:.4}"),
            format!("{:.3}", mse / full_mse.max(1e-12)),
            human_bytes(cf.total_bytes()),
        ]);
    }
    t.print();

    // paper setting: 12-bit fits, subsample
    let knee_bits: u32 = cfg.args.get_or("knee-bits", 12);
    println!("\n-- lower chart: subsampling ({knee_bits}-bit fits; paper keeps 600/1000) --");
    let (qf, _) = lossy::quantize_fits(&forest, knee_bits, lossy::QuantizeMethod::Uniform).unwrap();
    let mut t = Table::new(&["trees |A0|", "test MSE", "MSE/lossless", "size", "eq.7 bound"]);
    let n = cfg.trees;
    // σ² via per-tree deviations
    let sigma2 = {
        let rows = tt.test.num_rows();
        let ens: Vec<f64> = (0..rows).map(|r| qf.predict_regression(&tt.test, r)).collect();
        let per_tree: Vec<f64> = qf
            .trees
            .iter()
            .map(|t| {
                (0..rows)
                    .map(|r| match t.predict_row(&tt.test, r) {
                        rf_compress::forest::Fit::Regression(v) => v - ens[r],
                        _ => unreachable!(),
                    })
                    .sum::<f64>()
                    / rows as f64
            })
            .collect();
        theory::estimate_sigma2(&per_tree)
    };
    for keep in [n, n * 6 / 10, n * 4 / 10, n / 4, n / 10].into_iter().filter(|&k| k >= 2) {
        let sub = lossy::subsample_trees(&qf, keep, cfg.seed ^ 0xb1);
        let mse = sub.test_error(&tt.test);
        let (cf, _) = coord.run_job(&tt.train, &sub, &opts, 0.0).unwrap();
        t.row(&[
            keep.to_string(),
            format!("{mse:.4}"),
            format!("{:.3}", mse / full_mse.max(1e-12)),
            human_bytes(cf.total_bytes()),
            format!("{:.2e}", theory::combined_loss_bound(keep, sigma2, fit_range(&qf), knee_bits)),
        ]);
    }
    t.print();
    println!("\npaper endpoint: 12-bit fits + 600/1000 trees → 300 KB, MSE unchanged");
}

fn fit_range(forest: &rf_compress::forest::Forest) -> f64 {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for t in &forest.trees {
        for n in &t.nodes {
            if let rf_compress::forest::Fit::Regression(v) = n.fit {
                lo = lo.min(v);
                hi = hi.max(v);
            }
        }
    }
    (hi - lo).max(0.0)
}
