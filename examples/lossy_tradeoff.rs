//! The §7 lossy knobs on a small regression forest: fit quantization
//! (uniform vs dithered vs Lloyd–Max) and tree subsampling, with the eq. 7
//! theory printed next to measurements.
//!
//! ```text
//! cargo run --release --example lossy_tradeoff -- --trees 120 --bits 7
//! ```

use rf_compress::compress::{CompressOptions, CompressedForest};
use rf_compress::data::synthetic;
use rf_compress::forest::{Forest, ForestParams};
use rf_compress::lossy::{self, theory, QuantizeMethod};
use rf_compress::util::cli::Args;
use rf_compress::util::stats::human_bytes;
use rf_compress::util::Pcg64;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let trees = args.get_or("trees", 120usize);
    let bits = args.get_or("bits", 7u32);
    let ds = synthetic::airfoil_regression(42);
    let mut rng = Pcg64::new(9);
    let tt = ds.train_test_split(0.8, &mut rng);
    let forest = Forest::train(&tt.train, &ForestParams::regression(trees), 7);
    let opts = CompressOptions::default();
    let full = CompressedForest::compress(&forest, &tt.train, &opts)?;
    let full_mse = forest.test_error(&tt.test);
    println!(
        "lossless: {} trees, {} — test MSE {full_mse:.4}\n",
        trees,
        human_bytes(full.total_bytes())
    );

    println!("quantizer comparison at {bits} bits:");
    for (name, method) in [
        ("uniform", QuantizeMethod::Uniform),
        ("dithered", QuantizeMethod::Dithered { seed: 11 }),
        ("lloyd-max", QuantizeMethod::LloydMax),
    ] {
        let (qf, q) = lossy::quantize_fits(&forest, bits, method)?;
        let cf = CompressedForest::compress(&qf, &tt.train, &opts)?;
        let mse = qf.test_error(&tt.test);
        println!(
            "  {name:<10} size {} ({}% of lossless)  MSE {mse:.4} ({:+.2}%)  levels {}",
            human_bytes(cf.total_bytes()),
            cf.total_bytes() * 100 / full.total_bytes(),
            (mse / full_mse - 1.0) * 100.0,
            q.map(|q| q.levels.len()).unwrap_or(0)
        );
    }

    println!("\nsubsampling on top (uniform {bits}-bit fits):");
    let (qf, _) = lossy::quantize_fits(&forest, bits, QuantizeMethod::Uniform)?;
    for keep in [trees, trees / 2, trees / 4, trees / 8] {
        let sub = lossy::subsample_trees(&qf, keep, 5);
        let cf = CompressedForest::compress(&sub, &tt.train, &opts)?;
        let mse = sub.test_error(&tt.test);
        println!(
            "  {keep:>4} trees: {} — MSE {mse:.4}  (eq.7 excess bound σ²/|A0| ~ {:.1e})",
            human_bytes(cf.total_bytes()),
            theory::subsample_excess_variance(keep, 1.0) // σ²=1 scale reference
        );
    }
    println!("\npaper: 7-bit fits + 250/1000 trees reduced 340 KB → 11 KB at unchanged MSE");
    Ok(())
}
