//! **End-to-end driver** — exercises every layer of the stack on a real
//! small workload and reports the paper's headline metric (compression
//! ratios) plus serving latency/throughput:
//!
//! 1. L2/L1 artifacts: the XLA runtime loads the AOT-compiled JAX+Pallas
//!    Lloyd step (`make artifacts`) — clustering below runs through PJRT;
//! 2. forest substrate: trains `treeBagger`-style forests on three
//!    synthetic datasets (regression + binary + multiclass);
//! 3. Algorithm 1: compresses each, verifies bit-exact reconstruction,
//!    reports standard/light/ours sizes — the Table-2 metric;
//! 4. §7 lossy: quantizes + subsamples the regression forest and reports
//!    the rate/distortion point;
//! 5. L3 serving: loads everything into the model store, serves a batched
//!    TCP workload from the compressed bytes, and reports latency and
//!    throughput.
//!
//! The run is recorded in EXPERIMENTS.md.
//!
//! ```text
//! make artifacts && cargo run --release --example end_to_end
//! cargo run --release --example end_to_end -- --trees 100 --requests 500
//! ```

use rf_compress::compress::CompressOptions;
use rf_compress::coordinator::server::{Client, Server};
use rf_compress::coordinator::store::ModelStore;
use rf_compress::coordinator::Coordinator;
use rf_compress::data::{synthetic, Column, Dataset};
use rf_compress::lossy;
use rf_compress::util::cli::Args;
use rf_compress::util::stats::{human_bytes, OnlineStats};
use rf_compress::util::Pcg64;
use std::sync::Arc;
use std::time::Instant;

fn wire_row(ds: &Dataset, row: usize) -> String {
    ds.features
        .iter()
        .map(|f| match &f.column {
            Column::Numeric(v) => format!("{}", v[row]),
            Column::Categorical { values, .. } => format!("c{}", values[row]),
        })
        .collect::<Vec<_>>()
        .join(",")
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let trees = args.get_or("trees", 60usize);
    let n_requests = args.get_or("requests", 300usize);
    let total_t0 = Instant::now();

    // ---- 1. runtime + coordinator ----
    let mut coord = Coordinator::new();
    println!("[1/5] clustering engine: {}", coord.engine_name());

    // ---- 2+3. train + compress + verify three workloads ----
    let workloads: Vec<(&str, Dataset)> = vec![
        ("airfoil+ (regression)", synthetic::airfoil_regression(1234)),
        ("naval* (binary)", synthetic::naval_classification(1234)),
        ("iris (3-class)", synthetic::iris(1234)),
    ];
    let store = Arc::new(ModelStore::new());
    let mut datasets = Vec::new();
    println!("[2/5] training {} trees per forest; [3/5] compressing:", trees);
    for (name, ds) in workloads {
        let (forest, cf, report) =
            coord.train_and_compress(&ds, trees, 7, &CompressOptions::default())?;
        let restored = cf.decompress()?;
        assert!(restored.identical(&forest), "{name}: losslessness violated");
        println!(
            "  {name:<24} {} nodes  standard {:>10}  light {:>10}  ours {:>10}  (1:{:.1}/1:{:.1})  lossless ✓",
            report.total_nodes,
            human_bytes(report.standard_bytes),
            human_bytes(report.light_bytes),
            human_bytes(report.ours_bytes),
            report.standard_ratio(),
            report.light_ratio()
        );
        let key = name.split_whitespace().next().unwrap();
        store.insert(key, &cf)?;
        datasets.push((key.to_string(), ds, forest));
    }

    // ---- 4. lossy point on the regression forest ----
    let (_, airfoil_ds, airfoil_forest) = &datasets[0];
    let mut rng = Pcg64::new(3);
    let tt = airfoil_ds.train_test_split(0.8, &mut rng);
    let eval_forest = coord.train(&tt.train, trees, 7);
    let full_mse = eval_forest.test_error(&tt.test);
    let (qf, _) = lossy::quantize_fits(&eval_forest, 7, lossy::QuantizeMethod::Uniform)?;
    let sub = lossy::subsample_trees(&qf, (trees / 4).max(2), 5);
    let lossy_mse = sub.test_error(&tt.test);
    let (cf_lossless, _) = coord.run_job(&tt.train, &eval_forest, &CompressOptions::default(), 0.0)?;
    let (cf_lossy, _) = coord.run_job(&tt.train, &sub, &CompressOptions::default(), 0.0)?;
    println!(
        "[4/5] lossy (7-bit fits, |A0|={}): {} → {} ({:.1}x), MSE {:.4} → {:.4}",
        sub.num_trees(),
        human_bytes(cf_lossless.total_bytes()),
        human_bytes(cf_lossy.total_bytes()),
        cf_lossless.total_bytes() as f64 / cf_lossy.total_bytes() as f64,
        full_mse,
        lossy_mse
    );
    let _ = airfoil_forest;

    // ---- 5. serve a batched TCP workload ----
    let server = Server::start(store.clone(), 0)?;
    println!("[5/5] serving {} models ({}) on {}", store.len(), human_bytes(store.resident_bytes()), server.addr());
    let addr = server.addr();
    let t0 = Instant::now();
    let mut latency = OnlineStats::new();
    let n_clients = 4usize;
    let per_client = n_requests / n_clients;
    let stats: Vec<OnlineStats> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n_clients)
            .map(|c| {
                let datasets = &datasets;
                scope.spawn(move || {
                    let mut client = Client::connect(addr).unwrap();
                    let mut rng = Pcg64::new(100 + c as u64);
                    let mut local = OnlineStats::new();
                    for _ in 0..per_client {
                        let (key, ds, forest) = &datasets[rng.gen_index(datasets.len())];
                        let row = rng.gen_index(ds.num_rows());
                        let req = format!("PREDICT {key} {}", wire_row(ds, row));
                        let q0 = Instant::now();
                        let reply = client.request(&req).unwrap();
                        local.push(q0.elapsed().as_secs_f64() * 1e3);
                        assert!(reply.starts_with("OK "), "{reply}");
                        // verify against the original forest
                        let expect = if forest.classification {
                            format!("OK {}", forest.predict_class(ds, row))
                        } else {
                            format!("OK {}", forest.predict_regression(ds, row))
                        };
                        assert_eq!(reply, expect, "prediction from compressed store differs");
                    }
                    local
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for s in &stats {
        latency.merge(s);
    }
    let wall = t0.elapsed().as_secs_f64();
    let served = latency.count();
    println!(
        "      {served} requests / {n_clients} clients in {wall:.2}s → {:.0} req/s",
        served as f64 / wall
    );
    println!(
        "      latency: mean {:.2} ms, max {:.2} ms (every reply verified against the uncompressed forest)",
        latency.mean(),
        latency.max()
    );
    let st = store.stats();
    println!(
        "      store: {} requests in {} batches (mean batch {:.1})",
        st.requests,
        st.batches,
        st.requests as f64 / st.batches.max(1) as f64
    );
    server.stop();
    println!("\nend-to-end OK in {:.1}s — all layers composed", total_t0.elapsed().as_secs_f64());
    Ok(())
}
