//! Quickstart: train a random forest, compress it losslessly, look at the
//! size breakdown, reconstruct it bit-exactly, and predict straight from
//! the compressed bytes.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use rf_compress::compress::{CompressOptions, CompressedForest, CompressedPredictor};
use rf_compress::data::synthetic;
use rf_compress::forest::{Forest, ForestParams};
use rf_compress::util::stats::human_bytes;

fn main() -> anyhow::Result<()> {
    // 1. a dataset (synthetic stand-in for UCI Iris; use data::csv for real
    //    files) and a treeBagger-style forest
    let ds = synthetic::iris(42);
    let forest = Forest::train(&ds, &ForestParams::classification(100), 7);
    println!(
        "trained {} trees / {} nodes / mean depth {:.1}",
        forest.num_trees(),
        forest.total_nodes(),
        forest.mean_depth()
    );

    // 2. compress (Algorithm 1 of the paper)
    let cf = CompressedForest::compress(&forest, &ds, &CompressOptions::default())?;
    let cols = cf.sizes.paper_columns();
    println!("compressed to {}:", human_bytes(cf.total_bytes()));
    println!("  structure    {}", human_bytes(cols.structure));
    println!("  var names    {}", human_bytes(cols.var_names));
    println!("  split values {}", human_bytes(cols.split_values));
    println!("  fits         {}", human_bytes(cols.fits));
    println!("  dictionaries {}", human_bytes(cols.dict));

    // 3. perfect reconstruction
    let restored = cf.decompress()?;
    assert!(restored.identical(&forest));
    println!("decompression: bit-exact ✓");

    // 4. predictions straight from the compressed bytes (paper §5)
    let predictor = CompressedPredictor::new(cf.parse()?)?;
    let mut agree = 0;
    for row in 0..ds.num_rows() {
        let direct = forest.predict_class(&ds, row);
        match predictor.predict_row(&ds, row)? {
            rf_compress::compress::predict::PredictOne::Class(c) if c == direct => agree += 1,
            other => println!("row {row}: {other:?} != {direct}"),
        }
    }
    println!("compressed-format predictions agree on {agree}/{} rows ✓", ds.num_rows());
    Ok(())
}
