//! The subscriber-device scenario (paper §1): a model store holding many
//! compressed per-user forests, serving predictions over TCP straight from
//! the compressed bytes. Starts a server, drives a short client session,
//! prints store stats, and exits (pass `--keep-running` to stay up).
//!
//! ```text
//! cargo run --release --example model_store_server
//! cargo run --release --example model_store_server -- --port 7878 --keep-running
//! ```

use rf_compress::compress::CompressOptions;
use rf_compress::coordinator::server::{Client, Server};
use rf_compress::coordinator::store::ModelStore;
use rf_compress::coordinator::Coordinator;
use rf_compress::data::{synthetic, Column};
use rf_compress::util::cli::Args;
use rf_compress::util::stats::human_bytes;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let trees = args.get_or("trees", 30usize);
    let port: u16 = args.get_or("port", 0u16);

    // each "subscriber" gets a personal model
    let store = Arc::new(ModelStore::new());
    let mut coord = Coordinator::new();
    for (user, ds) in [
        ("alice", synthetic::iris(1)),
        ("bob", synthetic::wages(2)),
        ("carol", synthetic::airfoil_classification(3)),
    ] {
        let (_, cf, report) =
            coord.train_and_compress(&ds, trees, 7, &CompressOptions::default())?;
        store.insert(user, &cf)?;
        println!(
            "{user}: {} model stored ({} vs light {})",
            ds.name,
            human_bytes(report.ours_bytes),
            human_bytes(report.light_bytes)
        );
    }
    println!("store resident: {}\n", human_bytes(store.resident_bytes()));

    let server = Server::start(store.clone(), port)?;
    println!("serving on {}", server.addr());

    // client session
    let mut client = Client::connect(server.addr())?;
    println!("> LIST\n< {}", client.request("LIST")?);
    // query alice's model with a row from her dataset
    let ds = synthetic::iris(1);
    let wire = |row: usize| {
        ds.features
            .iter()
            .map(|f| match &f.column {
                Column::Numeric(v) => format!("{}", v[row]),
                Column::Categorical { values, .. } => format!("c{}", values[row]),
            })
            .collect::<Vec<_>>()
            .join(",")
    };
    for row in [0, 50, 100] {
        let req = format!("PREDICT alice {}", wire(row));
        println!("> {req}\n< {}", client.request(&req)?);
    }
    println!("> STATS\n< {}", client.request("STATS")?);
    println!("> BYTES\n< {}", client.request("BYTES")?);

    if args.flag("keep-running") {
        println!("(press ctrl-c to stop)");
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    server.stop();
    Ok(())
}
