//! The paper's §6 case study on (synthetic) Liberty Mutual data: regression
//! vs binarized classification, and where the bytes go in each.
//!
//! The paper's numbers (1000 trees, real data): regression 733.7 MB
//! standard / 215.6 light / 142.7 ours with fits dominating; classification
//! 723.1 / 96.5 / 12.43 MB with tiny fits. The reproduced *shape*: fits
//! dominate the regression forest and collapse after binarization, pushing
//! the ratio from ~1:1.5 to ~1:5+ vs light as trees grow.
//!
//! ```text
//! cargo run --release --example liberty_case_study -- --trees 60
//! ```

use rf_compress::compress::CompressOptions;
use rf_compress::coordinator::Coordinator;
use rf_compress::data::synthetic;
use rf_compress::util::cli::Args;
use rf_compress::util::stats::human_bytes;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let trees = args.get_or("trees", 40usize);
    let seed = args.get_or("seed", 7u64);
    let mut coord = Coordinator::new();
    println!("engine: {}; {trees} trees per forest\n", coord.engine_name());

    for (label, ds) in [
        ("Liberty+ (regression)", synthetic::liberty_regression(1234)),
        ("Liberty* (classification via mean threshold)", synthetic::liberty_classification(1234)),
    ] {
        println!("=== {label} ===");
        let (forest, cf, report) =
            coord.train_and_compress(&ds, trees, seed, &CompressOptions::default())?;
        assert!(cf.decompress()?.identical(&forest));
        let cols = cf.sizes.paper_columns();
        println!(
            "standard {} | light {} | ours {}  (1:{:.1} / 1:{:.1})",
            human_bytes(report.standard_bytes),
            human_bytes(report.light_bytes),
            human_bytes(report.ours_bytes),
            report.standard_ratio(),
            report.light_ratio()
        );
        println!(
            "ours breakdown: struct {} | vars {} | splits {} | fits {} | dict {}",
            human_bytes(cols.structure),
            human_bytes(cols.var_names),
            human_bytes(cols.split_values),
            human_bytes(cols.fits),
            human_bytes(cols.dict)
        );
        let fit_share = cols.fits as f64 / cf.total_bytes() as f64;
        println!("fits share of total: {:.0}%", fit_share * 100.0);
        println!(
            "clusters per family (paper §6: 2–3 at 64-bit α): {:?}\n",
            report.cluster_ks.iter().map(|(_, k)| *k).collect::<Vec<_>>()
        );
    }
    println!("paper shape to verify: regression fits dominate; classification fits are tiny");
    Ok(())
}
